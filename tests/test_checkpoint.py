"""Checkpoint/restart: atomic commit, async writer, resume bit-equality,
elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (AsyncCheckpointer, latest_step, load_checkpoint,
                        save_checkpoint)
from repro.configs.base import RunConfig, ShapeConfig, get_smoke_config
from repro.models import registry
from repro.train.step import init_state, make_train_step


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"m": [jnp.zeros((2,)), jnp.full((3,), 7.0)],
                "step": jnp.asarray(5, jnp.int32)},
        "mixed": (jnp.asarray([1, 2], jnp.int8),),
    }


def _assert_tree_equal(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_round_trip(tmp_path):
    t = _tree()
    d = save_checkpoint(str(tmp_path), t, 120, meta={"loss": 1.5})
    assert os.path.basename(d) == "step_00000120"
    t2, meta = load_checkpoint(str(tmp_path))
    _assert_tree_equal(t, t2)
    assert meta["loss"] == 1.5 and meta["step"] == 120


def test_latest_step_and_overwrite(tmp_path):
    save_checkpoint(str(tmp_path), _tree(), 1)
    save_checkpoint(str(tmp_path), _tree(), 3)
    save_checkpoint(str(tmp_path), _tree(), 2)
    assert latest_step(str(tmp_path)) == 3
    save_checkpoint(str(tmp_path), _tree(), 3)  # idempotent overwrite
    assert latest_step(str(tmp_path)) == 3


def test_no_partial_commit(tmp_path):
    """A crashed save (simulated) leaves no committed step dir."""
    class Boom(Exception):
        pass

    bad = {"x": jnp.ones((2,))}
    orig = np.save
    calls = {"n": 0}

    def exploding_save(f, arr, **kw):
        calls["n"] += 1
        raise Boom()

    np.save = exploding_save
    try:
        with pytest.raises(Boom):
            save_checkpoint(str(tmp_path), bad, 9)
    finally:
        np.save = orig
    assert latest_step(str(tmp_path)) is None
    assert not [d for d in os.listdir(tmp_path) if d.startswith("step_")]


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(_tree(), s)
    ck.close()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_resume_bit_equality(tmp_path):
    """Training resumed from a checkpoint matches uninterrupted training."""
    cfg = get_smoke_config("yi-6b")
    run = RunConfig(ce_block_v=64)
    shape = ShapeConfig("s", 16, 4, "train")
    step = jax.jit(make_train_step(cfg, run))

    def batch(i):
        return registry.synth_inputs(jax.random.PRNGKey(100 + i), cfg,
                                     shape, "train")

    s = init_state(jax.random.PRNGKey(0), cfg, run)
    for i in range(2):
        s, _ = step(s, batch(i))
    save_checkpoint(str(tmp_path), s, 2)
    for i in range(2, 4):
        s, _ = step(s, batch(i))
    ref_loss = None
    s_resumed, _ = load_checkpoint(str(tmp_path), 2)
    s_resumed = jax.tree.map(jnp.asarray, s_resumed)
    for i in range(2, 4):
        s_resumed, m = step(s_resumed, batch(i))
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_roundtrip(tmp_path):
    """Loading with target shardings device_puts onto the current mesh —
    the elastic-restart path (1 device here, arbitrary shapes)."""
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = jax.make_mesh((1,), ("data",))
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), t, 0)
    sh = {"w": NamedSharding(mesh, PartitionSpec("data", None))}
    t2, _ = load_checkpoint(str(tmp_path), 0, shardings=sh)
    assert t2["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(t["w"]))
