"""HLO cost walker: exact FLOPs on known programs, while-loop trip
multiplication, collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    res = hlo_cost.analyze(c.as_text())
    assert res["flops"] == 2 * 128 * 256 * 64


def test_scan_multiplies_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    for n in (1, 4, 9):
        ws = jax.ShapeDtypeStruct((n, 64, 64), jnp.float32)
        c = _compile(f, x, ws)
        res = hlo_cost.analyze(c.as_text())
        assert res["flops"] == n * 2 * 64 * 64 * 64, n
        # XLA's own analysis counts the body once — that's the bug we fix
        if n > 1:
            assert hlo_cost.xla_cost(c)["flops"] < res["flops"]


def test_nested_scan():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, wi):
                return ci @ wi, None
            y, _ = jax.lax.scan(inner, c, w)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 32, 32), jnp.float32)
    c = _compile(f, x, ws)
    res = hlo_cost.analyze(c.as_text())
    assert res["flops"] == 15 * 2 * 32 ** 3


def test_collective_bytes_counted():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under dryrun env)")


def test_bytes_nonzero_and_sane():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    res = hlo_cost.analyze(c.as_text())
    # dot reads 2x4MB and writes 4MB
    assert 12e6 <= res["hbm_bytes"] <= 20e6
