"""Carousel: storage tiers, stager (retries/hedging), delivery iterator,
on-demand transform, and the Fig. 4/5 discrete-event comparison."""

import numpy as np
import pytest

from repro.carousel.ddm import CarouselDDM
from repro.carousel.delivery import DeliveryIterator
from repro.carousel.simulator import SimParams, compare, simulate
from repro.carousel.stager import Stager
from repro.carousel.storage import (CacheFullError, ColdStore, DiskCache,
                                    TapeFile)
from repro.carousel.transform import make_packing_transform, pack_documents
from repro.data.synthetic import build_cold_store, synth_docs


# ---------------------------------------------------------------- DiskCache

def test_cache_pin_release_evict():
    c = DiskCache(100)
    c.put("a", b"x", 40, pin=True)
    c.put("b", b"y", 40, pin=True)
    with pytest.raises(CacheFullError):
        c.put("c", b"z", 40, pin=True)  # nothing evictable
    c.release("a")                       # now LRU-evictable
    c.put("c", b"z", 40, pin=True)
    assert "a" not in c and "b" in c and "c" in c
    assert c.evictions == 1
    assert c.peak_bytes == 80


def test_cache_prompt_release_frees_immediately():
    c = DiskCache(100)
    c.put("a", b"x", 60, pin=True)
    c.release("a", drop=True)
    assert c.used == 0 and "a" not in c


# ---------------------------------------------------------------- Stager

def test_stager_stages_all_and_announces():
    cold = ColdStore(drives=4)
    for i in range(10):
        cold.add(TapeFile(f"f{i}", size=10, payload=np.arange(i + 1)))
    cache = DiskCache(10_000)
    seen = []
    st = Stager(cold, cache, workers=4,
                on_available=lambda n: seen.append(n))
    st.submit_all([f"f{i}" for i in range(10)])
    assert st.wait(timeout=10)
    assert sorted(seen) == [f"f{i}" for i in range(10)]
    assert all(f"f{i}" in cache for i in range(10))
    st.shutdown()


def test_stager_retries_tape_faults():
    cold = ColdStore(drives=2, fault_rate=0.5, seed=42)
    for i in range(8):
        cold.add(TapeFile(f"f{i}", size=1, payload=i))
    cache = DiskCache(10_000)
    st = Stager(cold, cache, workers=2, max_attempts=20, backoff=0.001)
    st.submit_all([f"f{i}" for i in range(8)])
    assert st.wait(timeout=30)
    assert st.failed() == []
    assert cold.failed_reads > 0  # faults actually happened and were retried
    st.shutdown()


def test_stager_no_backoff_sleep_after_final_attempt():
    """A terminally failing file must be marked failed right after its
    last attempt — not one full backoff interval later."""
    import time
    cold = ColdStore(drives=1, fault_rate=1.0, seed=0)
    cold.add(TapeFile("f0", size=1, payload=b"x"))
    cache = DiskCache(100)
    st = Stager(cold, cache, workers=1, max_attempts=3, backoff=0.2)
    t0 = time.monotonic()
    st.submit("f0")
    assert st.wait(timeout=5, hedge_interval=0.005)
    elapsed = time.monotonic() - t0
    # attempts sleep 0.2 + 0.4 between retries; the old code slept an
    # extra 0.8 AFTER the final failure
    assert elapsed < 1.0, elapsed
    assert st.failed() == ["f0"]
    st.shutdown()


def test_stager_latency_window_bounded():
    cold = ColdStore(drives=4)
    n = 40
    for i in range(n):
        cold.add(TapeFile(f"f{i}", size=1, payload=i))
    cache = DiskCache(10_000)
    st = Stager(cold, cache, workers=4, latency_window=16)
    st.submit_all([f"f{i}" for i in range(n)])
    assert st.wait(timeout=10)
    assert len(st._latencies) <= 16  # rolling window, not unbounded
    # the cached sorted snapshot must stay consistent through window
    # overflow (it is bisect-maintained, never re-sorted) and serve the
    # same upper median a full sort would
    window = st._latencies
    assert st._lat_window._sorted == sorted(window)
    assert st._median_latency() == sorted(window)[len(window) // 2]
    st.shutdown()


def test_stager_transform_applied():
    cold = ColdStore(drives=2)
    docs = synth_docs(0, 8, vocab_size=64, mean_len=20)
    cold.add(TapeFile("s0", size=100, payload=docs))
    cache = DiskCache(10_000)
    st = Stager(cold, cache, transform=make_packing_transform(16))
    st.submit("s0")
    assert st.wait(timeout=10)
    packed = cache.get("s0")
    assert packed["tokens"].shape[1] == 16
    assert packed["tokens"].dtype == np.int32
    st.shutdown()


# ---------------------------------------------------------------- transform

def test_packing_shapes_and_labels():
    docs = [np.arange(2, 12, dtype=np.int32), np.arange(2, 7, dtype=np.int32)]
    out = pack_documents(docs, seq_len=8, pad_id=0, eod_id=1)
    T, L, M = out["tokens"], out["labels"], out["loss_mask"]
    assert T.shape == L.shape == M.shape and T.shape[1] == 8
    # labels are next-token shifted
    flat = np.concatenate([T[0], [L[0, -1]]])
    assert (L[0][:-1] == T[0][1:]).all()
    # mask is 0 where the target crosses an eod boundary or padding
    assert set(np.unique(M)) <= {0.0, 1.0}
    eod_positions = np.where(T == 1)
    for r, c in zip(*eod_positions):
        assert M[r, c] == 0.0  # predicting across the boundary is masked


def test_packing_mask_matches_stream_validity():
    docs = [np.arange(2, 30, dtype=np.int32)]
    out = pack_documents(docs, seq_len=16)
    assert out["loss_mask"].sum() > 0


# ---------------------------------------------------------------- delivery

def _mk_pipeline(n_shards=6, coarse=False, capacity=1 << 30):
    cold = build_cold_store(n_shards=n_shards, docs_per_shard=8,
                            vocab_size=64, mean_doc_len=32, drives=2,
                            mount_latency=0.002)
    cache = DiskCache(capacity)
    names = [f.name for f in cold.files()]
    st = Stager(cold, cache, transform=make_packing_transform(16), workers=2)
    st.submit_all(names)
    return st, cache, names


def test_delivery_fine_yields_batches():
    st, cache, names = _mk_pipeline()
    it = DeliveryIterator(st, cache, names, batch_rows=4)
    batches = list(it)
    assert batches, "no batches delivered"
    for b in batches[:-1]:
        assert b["tokens"].shape == (4, 16)
        assert set(b) == {"tokens", "labels", "loss_mask"}
    # the final batch may be the partial tail; never empty, never over
    assert 1 <= batches[-1]["tokens"].shape[0] <= 4
    assert it.rows_delivered == sum(b["tokens"].shape[0] for b in batches)
    # prompt release: nothing left pinned in the cache
    assert cache.stats()["entries"] == 0
    st.shutdown()


def test_delivery_emits_final_partial_batch():
    """Row conservation: delivered rows == dataset rows even when the
    dataset is not a multiple of batch_rows (the tail batch used to be
    silently dropped)."""
    cold = ColdStore(drives=2)
    rows_per_shard = 5
    for i in range(3):  # 15 rows total, batch_rows=4 -> 4+4+4+3
        cold.add(TapeFile(f"s{i}", size=10, payload={
            "x": np.arange(rows_per_shard * 2).reshape(rows_per_shard, 2)}))
    cache = DiskCache(1 << 20)
    st = Stager(cold, cache, workers=2)
    names = [f"s{i}" for i in range(3)]
    st.submit_all(names)
    it = DeliveryIterator(st, cache, names, batch_rows=4)
    batches = list(it)
    sizes = [b["x"].shape[0] for b in batches]
    assert sizes == [4, 4, 4, 3]
    assert sum(sizes) == 3 * rows_per_shard == it.rows_delivered
    st.shutdown()


def test_delivery_coarse_waits_then_yields():
    st, cache, names = _mk_pipeline(coarse=True)
    it = DeliveryIterator(st, cache, names, batch_rows=4, coarse=True)
    batches = list(it)
    assert batches
    assert it.first_batch_at is not None
    assert it.failed_shards == 0
    st.shutdown()


def _mk_faulty(n_shards=4, fault_rate=1.0, seed=0):
    """A pipeline whose tape reads fail (deterministically by seed)."""
    cold = ColdStore(drives=2, fault_rate=fault_rate, seed=seed)
    rows = 4
    for i in range(n_shards):
        cold.add(TapeFile(f"s{i}", size=10, payload={
            "x": np.arange(rows * 2).reshape(rows, 2)}))
    cache = DiskCache(1 << 20)
    st = Stager(cold, cache, workers=2, max_attempts=2, backoff=0.001)
    names = [f"s{i}" for i in range(n_shards)]
    st.submit_all(names)
    return st, cache, names


@pytest.mark.parametrize("coarse", [False, True])
def test_delivery_all_failed_shards_raise(coarse):
    """Terminal staging failure of EVERY shard must raise, not silently
    yield an empty iterator (both modes)."""
    st, cache, names = _mk_faulty(fault_rate=1.0)
    it = DeliveryIterator(st, cache, names, batch_rows=4, coarse=coarse,
                          timeout=20)
    with pytest.raises(RuntimeError, match="failed staging"):
        list(it)
    assert it.failed_shards == len(names)
    st.shutdown()


@pytest.mark.parametrize("coarse", [False, True])
def test_delivery_partial_failure_is_recorded(coarse):
    """Some shards fail terminally: the survivors are delivered and the
    skips are surfaced (failed_shards + skipped_shards), both modes."""
    cold = ColdStore(drives=2)
    rows = 4
    for i in range(4):
        cold.add(TapeFile(f"s{i}", size=10, payload={
            "x": np.arange(rows * 2).reshape(rows, 2)}))
    cache = DiskCache(1 << 20)

    real_read = cold.read

    def read(name):  # s1/s3 are unreadable, the rest stage fine
        if name in ("s1", "s3"):
            raise IOError(f"tape read error on {name}")
        return real_read(name)

    cold.read = read
    st = Stager(cold, cache, workers=2, max_attempts=2, backoff=0.001)
    names = [f"s{i}" for i in range(4)]
    st.submit_all(names)
    it = DeliveryIterator(st, cache, names, batch_rows=4, coarse=coarse,
                          timeout=20)
    batches = list(it)
    assert it.failed_shards == 2
    assert it.skipped_shards == ["s1", "s3"]
    assert sum(b["x"].shape[0] for b in batches) == 2 * rows
    st.shutdown()


def test_delivery_fine_starts_before_all_staged():
    """Fine mode must deliver its first batch while later shards are still
    on 'tape' — the carousel's whole point."""
    cold = build_cold_store(n_shards=8, docs_per_shard=8, vocab_size=64,
                            mean_doc_len=32, drives=1, mount_latency=0.03)
    cache = DiskCache(1 << 30)
    names = [f.name for f in cold.files()]
    st = Stager(cold, cache, transform=make_packing_transform(16), workers=1)
    st.submit_all(names)
    it = DeliveryIterator(st, cache, names, batch_rows=2, prefetch=1)
    first = next(iter(it))
    assert first["tokens"].shape == (2, 16)
    pending = [r for r in st.records.values() if r.finished is None]
    assert pending, "first batch should arrive before staging completes"
    st.shutdown()


# ---------------------------------------------------------------- simulator

def test_sim_fine_vs_coarse_reproduces_paper():
    out = compare(n_files=300, disk_capacity=1.0e12, hedge=True, seed=1)
    fine, coarse = out["fine"], out["coarse"]
    # Fig. 4: iDDS reduces job attempts a lot
    assert fine["attempts_per_job"] == 1.0
    assert coarse["attempts_per_job"] > 1.5
    # Fig. 5: smaller disk footprint, earlier first processing
    assert fine["peak_disk_TB"] < 0.5 * coarse["peak_disk_TB"]
    assert fine["ttfp_h"] < 0.1 * coarse["ttfp_h"]
    # and no worse end-to-end
    assert fine["makespan_h"] <= coarse["makespan_h"] * 1.05


def test_sim_disk_backpressure_respected():
    p = SimParams(n_files=100, disk_capacity=3.2e10, file_size=8e9,
                  granularity="fine", n_drives=4, seed=3)
    rep = simulate(p)
    assert rep.peak_disk <= p.disk_capacity + 1e-6


def test_sim_hedging_reduces_tail():
    base = dict(n_files=200, straggler_frac=0.15, straggler_mult=20.0,
                fault_rate=0.0, granularity="fine", seed=7,
                disk_capacity=4e12)
    slow = simulate(SimParams(**base, hedge=False))
    fast = simulate(SimParams(**base, hedge=True))
    assert fast.hedges > 0
    assert fast.makespan < slow.makespan


# ---------------------------------------------------------------- DDM glue

def test_carousel_ddm_prompt_release():
    cold = ColdStore(drives=2)
    cold.add(TapeFile("f0", size=50, payload=b"d"))
    cache = DiskCache(1000)
    ddm = CarouselDDM(cold, cache, prompt_release=True)
    ddm.register_from_cold("c0")
    cache.put("f0", b"d", 50, pin=False)
    ddm.set_available("c0", "f0")
    assert cache.used == 50
    ddm.mark_processed("c0", "f0")
    assert cache.used == 0  # released the moment processing finished
    assert ddm.get_collection("c0").n_processed == 1
