"""Push-delivery plane: the transactional outbox (messages journaled in
the same commit as the delivery state that caused them), the Publisher
daemon's batched fan-out over the bus and webhook channels, webhook
fault injection (500s, dropped connections, hangs) with per-attempt
journaling and circuit-breaking, exactly-once redelivery after a head
kill + recover on both store backends, claim adoption of the fan-out
singleton, and the long-poll / SSE / pagination REST surface.
"""
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import pytest

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.client import IDDSClient
from repro.core.daemons import Conductor, Publisher
from repro.core.delivery import UNDELIVERED_STATUSES, backoff_delay
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.spec import WorkflowSpec
from repro.core.store import BufferedStore, InMemoryStore, SqliteStore
from repro.core.workflow import FileRef

reg.register_payload("ob_echo", lambda params, inputs: {
    "inputs": list(inputs)})


def _wf(out="out.tape"):
    spec = WorkflowSpec("outbox-wf")
    spec.work("proc", payload="ob_echo", input_collection="tape",
              output_collection=out, granularity="fine", start={})
    return spec.build()


def _tape(idds, n=1):
    idds.ctx.ddm.register_collection(
        "tape", [FileRef(f"f{i}", size=1, available=True)
                 for i in range(n)])


def _publisher(idds) -> Publisher:
    return next(d for d in idds.daemons if isinstance(d, Publisher))


def _conductor(idds) -> Conductor:
    return next(d for d in idds.daemons if isinstance(d, Conductor))


def _disable_publisher(idds):
    """Simulate a head whose Publisher never got to run (crash before
    fan-out): outbox rows stay journaled ``new``."""
    _publisher(idds).__dict__["process_once"] = lambda: 0


@pytest.fixture(params=["memory", "sqlite"])
def shared_store(request, tmp_path):
    """Factory yielding fresh handles on ONE shared catalog (memory
    shares the instance, sqlite the WAL file) — the two-heads idiom."""
    if request.param == "memory":
        s = InMemoryStore()
        yield lambda: s
    else:
        path = str(tmp_path / "outbox.db")
        handles = []

        def make():
            h = SqliteStore(path)
            handles.append(h)
            return h

        yield make
        for h in handles:
            h.close()


class HookReceiver:
    """In-test webhook endpoint with scriptable failure modes.

    ``script`` is consumed one action per incoming POST: ``"ok"``
    answers 200, ``"500"`` answers a server error, ``"drop"`` closes
    the socket without any response, ``("hang", s)`` sleeps ``s``
    seconds (past the Publisher's timeout) before answering 200.  When
    the script runs out, ``default`` applies.  Accepted (200-answered)
    msg_ids accumulate in ``accepted``; every request that arrived —
    including failed ones — lands in ``requests``.
    """

    def __init__(self, script=(), default="ok"):
        self.script = list(script)
        self.default = default
        self.requests = []
        self.accepted = []
        self.lock = threading.Lock()
        recv = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):  # noqa: A003
                pass

            def do_POST(self):  # noqa: N802
                length = int(self.headers.get("Content-Length", 0) or 0)
                body = (json.loads(self.rfile.read(length))
                        if length else {})
                with recv.lock:
                    action = (recv.script.pop(0) if recv.script
                              else recv.default)
                    recv.requests.append(body)
                if isinstance(action, tuple) and action[0] == "hang":
                    time.sleep(action[1])
                    action = "ok"
                if action == "drop":
                    self.connection.close()
                    return
                if action == "500":
                    self.send_response(500)
                    self.send_header("Content-Length", "0")
                    self.end_headers()
                    return
                with recv.lock:
                    recv.accepted.extend(
                        d["msg_id"] for d in body.get("deliveries", []))
                payload = b"{}"
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)

        self.httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        self.thread = threading.Thread(target=self.httpd.serve_forever,
                                       daemon=True)
        self.thread.start()

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.httpd.server_address[1]}/hook"

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()


@pytest.fixture
def receiver():
    r = HookReceiver()
    yield r
    r.close()


# --------------------------------------------------------- backoff helper

def test_backoff_delay_full_jitter_shape():
    # rng pinned to the extremes bounds the jitter window
    assert backoff_delay(1.0, 0, rng=lambda: 0.0) == 0.5
    assert backoff_delay(1.0, 0, rng=lambda: 1.0) == 1.5
    # exponential in the attempt number, capped
    assert backoff_delay(1.0, 3, rng=lambda: 0.5) == 8.0
    assert backoff_delay(1.0, 10, rng=lambda: 0.5) == 30.0  # cap
    assert backoff_delay(1.0, 10, rng=lambda: 0.5, cap=4.0) == 4.0
    # base 0 collapses the schedule to immediate (test knob)
    assert backoff_delay(0.0, 5) == 0.0
    # negative attempts clamp to the base step
    assert backoff_delay(1.0, -3, rng=lambda: 0.5) == 1.0


# ----------------------------------------------- transactional journaling

@pytest.mark.parametrize("kind", ["memory", "sqlite"])
def test_outbox_rows_journaled_with_deliveries(kind, tmp_path):
    """Every created delivery journals one outbox row in the same
    commit; with the Publisher off they sit ``new`` in the store."""
    store = (InMemoryStore() if kind == "memory"
             else SqliteStore(str(tmp_path / "j.db")))
    idds = IDDS(store=store)
    _disable_publisher(idds)
    sub = idds.subscribe("trainer", ["out.*"])
    _tape(idds, n=3)
    idds.submit_workflow(_wf())
    idds.pump()
    dl = idds.list_deliveries(sub["sub_id"])
    assert dl["total"] == 3
    msgs = store.load_messages()
    assert len(msgs) == 3
    by_delivery = {m["delivery_id"] for m in msgs}
    assert by_delivery == {d["delivery_id"] for d in dl["deliveries"]}
    for m in msgs:
        assert m["status"] == "new" and m["channel"] == "bus"
        assert m["attempts"] == 0 and m["sub_id"] == sub["sub_id"]
        assert m["collection"] == "out.tape" and m["seq"] >= 1
    assert store.count_messages(statuses=UNDELIVERED_STATUSES) == 3
    # seq is a strictly increasing cursor; after_seq resumes past it
    seqs = [m["seq"] for m in msgs]
    assert seqs == sorted(seqs) and len(set(seqs)) == 3
    tail = store.load_messages(after_seq=seqs[0])
    assert [m["seq"] for m in tail] == seqs[1:]
    idds.close()


@pytest.mark.parametrize("kind", ["memory", "sqlite"])
def test_message_upsert_preserves_seq(kind, tmp_path):
    store = (InMemoryStore() if kind == "memory"
             else SqliteStore(str(tmp_path / "u.db")))
    store.save_messages([{"msg_id": "m1", "sub_id": "s1",
                          "status": "new", "not_before": None,
                          "created_at": 1.0}])
    (row,) = store.load_messages()
    first_seq = row["seq"]
    row["status"] = "delivered"
    store.save_messages([row])
    (row2,) = store.load_messages()
    assert row2["seq"] == first_seq and row2["status"] == "delivered"
    # filters: status set, sub_id, ripeness gate
    assert store.load_messages(statuses=("new",)) == []
    assert store.count_messages(statuses=("delivered",)) == 1
    store.save_messages([{"msg_id": "m2", "sub_id": "s2",
                          "status": "queued", "not_before": 50.0,
                          "created_at": 2.0}])
    assert [m["msg_id"] for m in store.load_messages(sub_id="s2")] \
        == ["m2"]
    ripe = store.load_messages(statuses=UNDELIVERED_STATUSES,
                               due_before=10.0)
    assert ripe == []  # m2 parked until 50.0
    ripe = store.load_messages(statuses=UNDELIVERED_STATUSES,
                               due_before=60.0)
    assert [m["msg_id"] for m in ripe] == ["m2"]
    store.close()


def test_buffered_store_never_buffers_outbox(tmp_path):
    """Outbox rows are the crash-safety mechanism: they bypass the
    write-coalescing buffer and land in the inner store immediately,
    while content rows sit buffered until a flush."""
    inner = SqliteStore(str(tmp_path / "b.db"))
    bs = BufferedStore(inner, flush_interval_ms=60_000)
    bs.save_contents("c", [FileRef("f0").to_dict()])
    assert bs.pending() == 1  # contents buffered
    bs.save_messages([{"msg_id": "m1", "sub_id": "s",
                       "status": "new", "not_before": None,
                       "created_at": 1.0}])
    assert bs.pending() == 1  # messages did NOT enter the buffer
    assert len(inner.load_messages()) == 1
    # message loads flush first, so reads see buffered writes too
    bs.load_messages()
    assert bs.pending() == 0
    bs.close()


# ------------------------------------------------------- bus-channel fan-out

def test_publisher_bus_fanout_addressed_notify():
    idds = IDDS()
    seen = []
    idds.ctx.bus.subscribe(M.T_CONSUMER_NOTIFY,
                           lambda m: seen.append(m.body))
    sub = idds.subscribe("trainer", ["out.*"])
    _tape(idds, n=2)
    idds.submit_workflow(_wf())
    idds.pump()
    msgs = idds.store.load_messages()
    assert len(msgs) == 2
    assert all(m["status"] == "delivered" and m["attempts"] == 1
               for m in msgs)
    # the Publisher's addressed notifications carry the routing fields
    addressed = [b for b in seen if b.get("msg_id")]
    assert {b["msg_id"] for b in addressed} \
        == {m["msg_id"] for m in msgs}
    for b in addressed:
        assert b["sub_id"] == sub["sub_id"]
        assert b["delivery_id"] and b["collection"] == "out.tape"
    assert idds.stats["outbox_published"] == 2
    idds.close()


def test_outbox_depth_gauge_and_channel_counters():
    idds = IDDS()
    idds.subscribe("trainer", ["out.*"])
    _tape(idds, n=2)
    idds.submit_workflow(_wf())
    idds.pump()
    text = idds.metrics_text()
    (line,) = [ln for ln in text.splitlines()
               if ln.startswith("idds_outbox_deliveries_total{")]
    assert 'channel="bus"' in line and line.endswith(" 2")
    assert "idds_outbox_depth" in text
    # drained: the depth gauge reads 0
    for line in text.splitlines():
        if line.startswith("idds_outbox_depth{"):
            assert float(line.rsplit(" ", 1)[1]) == 0.0
    idds.close()


# ---------------------------------------------------------- webhook channel

def test_webhook_happy_path_batches_one_post(receiver):
    """N available files for one webhook subscription arrive as ONE
    batched POST, not N requests."""
    idds = IDDS()
    idds.subscribe("hooked", ["out.*"], push_url=receiver.url)
    _tape(idds, n=3)
    idds.submit_workflow(_wf())
    idds.pump()
    assert len(receiver.requests) == 1  # batched fan-out
    (batch,) = receiver.requests
    assert len(batch["deliveries"]) == 3
    assert len({d["file"] for d in batch["deliveries"]}) == 3
    assert all(d["collection"] == "out.tape"
               for d in batch["deliveries"])
    msgs = idds.store.load_messages()
    assert all(m["status"] == "delivered" and m["channel"] == "webhook"
               for m in msgs)
    assert set(receiver.accepted) == {m["msg_id"] for m in msgs}
    idds.close()


def test_webhook_flaky_500s_retry_with_journaled_attempts():
    recv = HookReceiver(script=["500", "500"])
    try:
        idds = IDDS()
        pub = _publisher(idds)
        pub.backoff_base = 0.0  # immediate retries (full jitter of 0)
        idds.subscribe("hooked", ["out.*"], push_url=recv.url)
        _tape(idds, n=1)
        idds.submit_workflow(_wf())
        idds.pump_until(
            lambda: idds.store.count_messages(
                statuses=("delivered",)) == 1,
            timeout=20, interval=0.01)
        (m,) = idds.store.load_messages()
        assert m["attempts"] == 3  # two failures + the success, journaled
        assert len(recv.requests) == 3
        # exactly-once acceptance despite the retries
        assert recv.accepted == [m["msg_id"]]
    finally:
        recv.close()


def test_webhook_drop_and_hang_then_recover():
    """A connection dropped mid-request and a response slower than the
    Publisher's timeout both count as failed attempts and retry."""
    recv = HookReceiver(script=["drop", ("hang", 0.8)])
    try:
        idds = IDDS()
        pub = _publisher(idds)
        pub.backoff_base = 0.0
        pub.webhook_timeout = 0.2  # the hang outlives this
        idds.subscribe("hooked", ["out.*"], push_url=recv.url)
        _tape(idds, n=1)
        idds.submit_workflow(_wf())
        idds.pump_until(
            lambda: idds.store.count_messages(
                statuses=("delivered",)) == 1,
            timeout=20, interval=0.01)
        (m,) = idds.store.load_messages()
        assert m["attempts"] == 3
        assert recv.accepted.count(m["msg_id"]) >= 1
    finally:
        recv.close()


def test_webhook_backoff_schedule_journaled():
    """A failed attempt parks the row ``queued`` with a full-jitter
    ``not_before`` in the configured window, journaled per attempt."""
    recv = HookReceiver(default="500")
    try:
        idds = IDDS()
        pub = _publisher(idds)
        pub.backoff_base = 0.5
        idds.subscribe("hooked", ["out.*"], push_url=recv.url)
        _tape(idds, n=1)
        idds.submit_workflow(_wf())
        idds.pump()  # quiesces once the row is parked in the future
        (m,) = idds.store.load_messages()
        assert m["status"] == "queued" and m["attempts"] == 1
        # attempt 1 -> step = base * 2^1 = 1.0, jitter 0.5x..1.5x
        delay = m["not_before"] - m["updated_at"]
        assert 0.5 <= delay <= 1.5
    finally:
        recv.close()
        idds.close()


def test_webhook_circuit_breaks_to_failed():
    """An endpoint that never answers 2xx exhausts the attempt budget:
    the message fails terminally and the tracked delivery is
    circuit-broken so the Conductor stops re-notifying it."""
    recv = HookReceiver(default="500")
    try:
        idds = IDDS()
        pub = _publisher(idds)
        pub.backoff_base = 0.0
        pub.max_notify_attempts = 3
        cond = _conductor(idds)
        cond.retry_interval = 30.0  # keep the Conductor's retries out
        sub = idds.subscribe("hooked", ["out.*"], push_url=recv.url)
        _tape(idds, n=1)
        idds.submit_workflow(_wf())
        idds.pump_until(
            lambda: idds.store.count_messages(
                statuses=("failed",)) == 1,
            timeout=20, interval=0.01)
        (m,) = idds.store.load_messages()
        assert m["attempts"] == 3 and len(recv.requests) == 3
        (d,) = idds.list_deliveries(sub["sub_id"])["deliveries"]
        assert d["status"] == "failed"
        assert idds.stats["deliveries_failed"] == 1
        (line,) = [ln for ln in idds.metrics_text().splitlines()
                   if ln.startswith("idds_outbox_failed_total{")]
        assert 'channel="webhook"' in line
    finally:
        recv.close()
        idds.close()


# ------------------------------------------- crash / recover / exactly-once

def test_exactly_once_after_head_kill(shared_store, receiver):
    """Outbox rows journaled by a head that dies before its Publisher
    ran are fanned out by the successor exactly once per message —
    kill-one-head-mid-stream loses zero notifications."""
    h1 = IDDS(store=shared_store(), head_id="head-1")
    _disable_publisher(h1)  # crash window: journaled, never published
    sub = h1.subscribe("hooked", ["out.*"], push_url=receiver.url)
    _tape(h1, n=4)
    h1.submit_workflow(_wf())
    h1.pump()
    original = h1.store.load_messages()
    assert len(original) == 4
    assert all(m["status"] == "new" for m in original)
    assert receiver.accepted == []  # nothing reached the consumer yet
    # head-1 is SIGKILLed: no close, no handoff — the journal is all
    h2 = IDDS(store=shared_store(), head_id="head-2")
    counts = h2.recover()
    assert counts["outbox_messages"] == 4
    assert counts["subscriptions"] == 1
    h2.pump_until(
        lambda: h2.store.count_messages(
            statuses=UNDELIVERED_STATUSES) == 0,
        timeout=20, interval=0.01)
    # zero lost: every journaled delivery reached the endpoint...
    delivered_ids = {d["delivery_id"]
                     for req in receiver.requests
                     for d in req["deliveries"]}
    assert delivered_ids == {m["delivery_id"] for m in original}
    # ...and exactly once per message (msg_id never accepted twice)
    assert len(receiver.accepted) == len(set(receiver.accepted))
    assert {m["msg_id"] for m in original} <= set(receiver.accepted)
    # the journal converged: every row terminal on the shared store
    for m in h2.store.load_messages():
        assert m["status"] == "delivered"
    # the hydrated subscription still tracks the deliveries
    assert h2.list_deliveries(sub["sub_id"])["total"] == 4


def test_redelivery_after_crash_between_send_and_journal(tmp_path,
                                                         receiver):
    """A head dying between the webhook POST and the status commit
    re-sends after recovery (at-least-once on the wire); consumers
    deduplicate on msg_id and the journal converges exactly-once."""
    path = str(tmp_path / "redeliver.db")
    s1 = SqliteStore(path)
    h1 = IDDS(store=s1, head_id="head-1")
    cond = _conductor(h1)
    cond.retry_interval = 30.0
    _disable_publisher(h1)
    h1.subscribe("hooked", ["out.*"], push_url=receiver.url)
    _tape(h1, n=2)
    h1.submit_workflow(_wf())
    h1.pump()
    pub = _publisher(h1)
    del pub.__dict__["process_once"]  # publisher back online...
    # ...but its status commit never lands (crash right after the POST)
    s1.save_messages = lambda msgs: None
    pub.process_once()
    assert len(receiver.accepted) == 2  # on the wire
    assert all(m["status"] == "new" for m in s1.load_messages())
    s1.close()
    # successor recovers the same store and drains again
    s2 = SqliteStore(path)
    h2 = IDDS(store=s2, head_id="head-2")
    _conductor(h2).retry_interval = 30.0
    assert h2.recover()["outbox_messages"] == 2
    h2.pump_until(
        lambda: s2.count_messages(statuses=UNDELIVERED_STATUSES) == 0,
        timeout=20, interval=0.01)
    # duplicates on the wire, bounded: each msg_id at most twice, and
    # the msg_id set is exactly the journal's (dedup key works)
    msgs = s2.load_messages()
    assert all(m["status"] == "delivered" for m in msgs)
    assert set(receiver.accepted) == {m["msg_id"] for m in msgs}
    for mid in set(receiver.accepted):
        assert receiver.accepted.count(mid) <= 2
    s2.close()


def test_publisher_claim_adoption(shared_store, receiver):
    """The fan-out singleton: while head-1 holds the outbox claim no
    peer drains; once the claim expires head-2 adopts the backlog."""
    ttl = 0.4
    h1 = IDDS(store=shared_store(), head_id="head-1", claim_ttl=ttl)
    # head-1's Publisher takes the claim (empty outbox, just the CAS)
    assert _publisher(h1).process_once() == 0
    (c,) = [c for c in h1.store.list_claims("outbox")]
    assert c["owner_id"] == "head-1"
    # head-2 produces outbox rows but cannot fan out while the claim
    # is live
    h2 = IDDS(store=shared_store(), head_id="head-2", claim_ttl=ttl)
    h2.subscribe("hooked", ["out.*"], push_url=receiver.url)
    _tape(h2, n=2)
    h2.submit_workflow(_wf())
    h2.pump()
    assert h2.store.count_messages(statuses=UNDELIVERED_STATUSES) == 2
    assert receiver.accepted == []
    # head-1 dies; its claim expires; head-2's Publisher adopts
    time.sleep(ttl * 1.3)
    h2.pump_until(
        lambda: h2.store.count_messages(
            statuses=UNDELIVERED_STATUSES) == 0,
        timeout=20, interval=0.02)
    assert len(set(receiver.accepted)) == 2
    (c,) = [c for c in h2.store.list_claims("outbox")]
    assert c["owner_id"] == "head-2"


# --------------------------------------------------------- REST push surface

@pytest.fixture
def gateway():
    gw = RestGateway(IDDS())
    gw.start()
    yield gw
    gw.stop()


def test_rest_subscriptions_and_deliveries_pagination(gateway):
    client = IDDSClient(gateway.url)
    idds = gateway.idds
    subs = [client.subscribe(f"c{i}") for i in range(4)]
    page = client.list_subscriptions(limit=2, offset=1)
    assert page["total"] == 4 and len(page["subscriptions"]) == 2
    assert page["limit"] == 2 and page["offset"] == 1
    sid = subs[0]["sub_id"]
    _tape(idds, n=3)
    idds.submit_workflow(_wf())
    idds.pump()
    dl = client.list_deliveries(sid, limit=2)
    assert dl["total"] == 3 and len(dl["deliveries"]) == 2
    rest = client.list_deliveries(sid, limit=10, offset=2)
    assert len(rest["deliveries"]) == 1
    # stable order: the pages tile the full listing without overlap
    all_ids = [d["delivery_id"]
               for d in client.list_deliveries(sid)["deliveries"]]
    assert [d["delivery_id"] for d in dl["deliveries"]] \
        + [d["delivery_id"] for d in rest["deliveries"]] == all_ids


def test_rest_pagination_validation(gateway):
    client = IDDSClient(gateway.url)
    sub = client.subscribe("c1")
    for bad in ("?limit=x", "?offset=-1", "?limit=-2"):
        status = _raw_get(
            gateway, f"/v1/subscriptions/{sub['sub_id']}/deliveries{bad}")
        assert status == 400, bad
    assert _raw_get(gateway, "/v1/subscriptions?limit=zz") == 400
    assert _raw_get(
        gateway,
        f"/v1/subscriptions/{sub['sub_id']}/events?after=zz") == 400
    assert _raw_get(
        gateway,
        f"/v1/subscriptions/{sub['sub_id']}/deliveries?wait_s=x") == 400


def _raw_get(gateway, path) -> int:
    import http.client
    conn = http.client.HTTPConnection(gateway.host, gateway.port)
    try:
        conn.request("GET", path)
        return conn.getresponse().status
    finally:
        conn.close()


def test_rest_long_poll_wakes_on_delivery(gateway):
    client = IDDSClient(gateway.url)
    idds = gateway.idds
    sub = client.subscribe("waiter", ["out.*"])
    out = {}

    def park():
        t0 = time.monotonic()
        res = client.wait_deliveries(sub["sub_id"], wait_s=10.0)
        out["n"], out["t"] = res["total"], time.monotonic() - t0

    t = threading.Thread(target=park, daemon=True)
    t.start()
    time.sleep(0.25)  # the handler is parked on the condition by now
    _tape(idds, n=1)
    idds.submit_workflow(_wf())
    idds.pump()
    t.join(timeout=12)
    assert out["n"] == 1
    assert out["t"] < 8.0  # woke on the event, not the timeout


def test_rest_sse_stream_and_resume(gateway):
    client = IDDSClient(gateway.url)
    idds = gateway.idds
    sub = client.subscribe("streamer", ["out.*"])
    got = []

    def consume():
        for ev in client.events(sub["sub_id"], wait_s=8.0):
            got.append(ev)
            if len(got) >= 3:
                break

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    time.sleep(0.2)
    _tape(idds, n=3)
    idds.submit_workflow(_wf())
    idds.pump()
    t.join(timeout=12)
    assert len(got) == 3
    seqs = [e["seq"] for e in got]
    assert seqs == sorted(seqs)
    # Last-Event-ID resume: replays only the journaled rows past the
    # cursor — a reconnecting consumer misses nothing, duplicates
    # nothing
    resumed = list(client.events(sub["sub_id"], after_seq=seqs[0],
                                 wait_s=0.3))
    assert [e["seq"] for e in resumed] == seqs[1:]
    assert all(e["delivery_id"] for e in resumed)


def test_rest_subscribe_push_url_validation(gateway):
    client = IDDSClient(gateway.url)
    sub = client.subscribe("hooked", push_url="http://127.0.0.1:9/x")
    assert sub["push_url"] == "http://127.0.0.1:9/x"
    from repro.core.client import IDDSClientError
    with pytest.raises(IDDSClientError):
        client.subscribe("bad", push_url="ftp://nope")


def test_publish_ack_latency_histogram(gateway):
    client = IDDSClient(gateway.url)
    idds = gateway.idds
    sub = client.subscribe("acker", ["out.*"])
    _tape(idds, n=1)
    idds.submit_workflow(_wf())
    idds.pump()
    (d,) = client.list_deliveries(sub["sub_id"])["deliveries"]
    client.ack(sub["sub_id"], [d["delivery_id"]])
    text = client.metrics()
    (count_line,) = [
        line for line in text.splitlines()
        if line.startswith("idds_outbox_publish_ack_seconds_count")]
    assert float(count_line.rsplit(" ", 1)[1]) == 1.0
