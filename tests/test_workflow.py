"""DG workflow engine semantics (paper Fig. 3): templates, conditions,
cycles, JSON round trip — and the declarative WorkflowSpec builder that
produces the same serializable Workflow."""

import pytest

from repro.core import payloads as reg
from repro.core.spec import WorkflowSpec
from repro.core.workflow import (Branch, Condition, WorkStatus, Workflow,
                                 WorkTemplate)


@pytest.fixture(autouse=True)
def _payloads():
    reg.register_payload("t_echo", lambda params, inputs: dict(params))
    yield


def build_wf():
    wf = Workflow(name="t")
    wf.add_template(WorkTemplate(name="a", payload="t_echo",
                                 defaults={"x": 1}))
    wf.add_template(WorkTemplate(name="b", payload="t_echo"))
    wf.add_condition(Condition(trigger="a", predicate="always",
                               true_next=[Branch("b")]))
    wf.add_initial("a", {"x": 5})
    return wf


def test_instantiation_binds_params():
    wf = build_wf()
    works = wf.start()
    assert len(works) == 1
    assert works[0].template == "a"
    assert works[0].params == {"x": 5}  # override beats default


def test_defaults_apply():
    wf = build_wf()
    w = wf.instantiate("a", {})
    assert w.params == {"x": 1}


def test_condition_fires_on_termination():
    wf = build_wf()
    (a,) = wf.start()
    a.status = WorkStatus.FINISHED
    a.result = {}
    new = wf.on_terminated(a)
    assert [w.template for w in new] == ["b"]
    assert new[0].iteration == 1


def test_false_branch():
    reg.register_payload("t_noop2", lambda p, i: {})
    wf = Workflow(name="t2")
    wf.add_template(WorkTemplate(name="a", payload="t_noop2"))
    wf.add_template(WorkTemplate(name="yes", payload="t_noop2"))
    wf.add_template(WorkTemplate(name="no", payload="t_noop2"))
    wf.add_condition(Condition(trigger="a", predicate="result_true",
                               true_next=[Branch("yes")],
                               false_next=[Branch("no")]))
    (a,) = [wf.instantiate("a", {})]
    a.status = WorkStatus.FINISHED
    a.result = {"decision": False}
    new = wf.on_terminated(a)
    assert [w.template for w in new] == ["no"]


def test_cycle_guard():
    """a -> a cycle stops at max_iterations."""
    reg.register_payload("t_noop3", lambda p, i: {})
    wf = Workflow(name="cyc")
    wf.add_template(WorkTemplate(name="a", payload="t_noop3"))
    wf.add_condition(Condition(trigger="a", predicate="always",
                               true_next=[Branch("a")], max_iterations=3))
    w = wf.instantiate("a", {})
    n = 0
    while True:
        w.status = WorkStatus.FINISHED
        w.result = {}
        nxt = wf.on_terminated(w)
        if not nxt:
            break
        (w,) = nxt
        n += 1
    assert n == 3


def test_fanout_binder():
    reg.register_payload("t_noop4", lambda p, i: {})
    reg.register_binder("t_fan3", lambda params, result: [
        {"i": i} for i in range(3)])
    wf = Workflow(name="fan")
    wf.add_template(WorkTemplate(name="a", payload="t_noop4"))
    wf.add_template(WorkTemplate(name="b", payload="t_noop4"))
    wf.add_condition(Condition(trigger="a", true_next=[
        Branch("b", binder="t_fan3")]))
    w = wf.instantiate("a", {})
    w.status = WorkStatus.FINISHED
    new = wf.on_terminated(w)
    assert sorted(x.params["i"] for x in new) == [0, 1, 2]


def test_json_round_trip():
    wf = build_wf()
    wf.start()
    j = wf.to_json()
    wf2 = Workflow.from_json(j)
    assert wf2.to_json() == j
    assert wf2.name == wf.name
    assert set(wf2.templates) == {"a", "b"}
    assert len(wf2.conditions) == 1
    assert len(wf2.works) == 1
    # deserialized workflow still evaluates conditions
    w = next(iter(wf2.works.values()))
    w.status = WorkStatus.FINISHED
    w.result = {}
    assert [x.template for x in wf2.on_terminated(w)] == ["b"]


def test_collection_formatting():
    reg.register_payload("t_noop5", lambda p, i: {})
    wf = Workflow(name="fmt")
    wf.add_template(WorkTemplate(
        name="a", payload="t_noop5",
        input_collection="in-{dataset}",
        output_collection="out-{dataset}-{workflow}"))
    w = wf.instantiate("a", {"dataset": "d1"})
    assert w.input_collection == "in-d1"
    assert w.output_collection == f"out-d1-{wf.workflow_id}"


def test_unknown_template_rejected():
    wf = Workflow(name="x")
    with pytest.raises(KeyError):
        wf.add_initial("nope", {})
    wf.add_template(WorkTemplate(name="a", payload="noop"))
    with pytest.raises(KeyError):
        wf.add_condition(Condition(trigger="zz"))


def test_workflow_finished_counts():
    wf = build_wf()
    wf.start()
    assert not wf.finished
    for w in wf.works.values():
        w.status = WorkStatus.FINISHED
    assert wf.finished
    assert wf.counts() == {"finished": 1}


# ---------------------------------------------------- WorkflowSpec builder

def test_spec_builds_same_shape_as_hand_wired():
    spec = WorkflowSpec("t")
    b = spec.work("b", payload="t_echo")
    spec.work("a", payload="t_echo", defaults={"x": 1}) \
        .when("always", then=b) \
        .start({"x": 5})
    built = spec.build().to_dict()
    hand = build_wf().to_dict()
    for key in ("templates", "conditions", "initial"):
        assert built[key] == hand[key], key


def test_spec_then_chains_and_returns_target():
    spec = WorkflowSpec("chain")
    a = spec.work("a", payload="t_echo", start={})
    b = spec.work("b", payload="t_echo")
    c = spec.work("c", payload="t_echo")
    assert a.then(b).then(c) is c
    wf = spec.build()
    assert [cond.trigger for cond in wf.conditions] == ["a", "b"]
    assert wf.conditions[0].true_next[0].template == "b"
    assert wf.conditions[1].true_next[0].template == "c"


def test_spec_when_branches_binders_and_fanout_start():
    spec = WorkflowSpec("w")
    yes = spec.work("yes", payload="t_echo")
    spec.work("no", payload="t_echo")
    spec.work("a", payload="t_echo",
              start=[{"i": 0}, {"i": 1}]) \
        .when("result_true", then=[(yes, "increment_round")],
              otherwise="no", max_iterations=7)
    wf = spec.build()
    (cond,) = wf.conditions
    assert cond.predicate == "result_true"
    assert cond.max_iterations == 7
    assert [(br.template, br.binder) for br in cond.true_next] == [
        ("yes", "increment_round")]
    assert [br.template for br in cond.false_next] == ["no"]
    assert wf.initial == [("a", {"i": 0}), ("a", {"i": 1})]


def test_spec_validation():
    spec = WorkflowSpec("v")
    spec.work("a", payload="t_echo")
    with pytest.raises(ValueError):
        spec.work("a", payload="t_echo")  # declared twice
    with pytest.raises(KeyError):
        spec._resolve("ghost")  # unknown branch target
    other = WorkflowSpec("other")
    foreign = other.work("x", payload="t_echo")
    with pytest.raises(ValueError):
        spec.work("b", payload="t_echo").then(foreign)


def test_spec_workflow_round_trips_to_json():
    spec = WorkflowSpec("rt")
    spec.work("a", payload="t_echo", start={"x": 2}) \
        .then("a", max_iterations=2)  # a self-cycle: DG, not DAG
    wf = spec.build()
    wf2 = Workflow.from_json(wf.to_json())
    assert wf2.to_json() == wf.to_json()
