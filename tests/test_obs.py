"""Telemetry plane: metrics registry semantics, Prometheus exposition,
lifecycle tracing, cluster-wide aggregation, and the observability REST
surface (/v1/metrics, /v1/requests/<id>/trace, cached healthz tallies).
"""
import threading
import time

import pytest

from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.obs import (BUCKETS, MetricsRegistry, build_trace,
                            parse_exposition, render_snapshots)
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.spec import WorkflowSpec
from repro.core.store import InMemoryStore
from repro.core.workflow import Workflow, WorkTemplate


def _noop_workflow(n=1):
    spec = WorkflowSpec("obs-test")
    for i in range(n):
        spec.work(f"w{i}", payload="noop", start={})
    return spec.build()


# ------------------------------------------------------------ registry

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry(head_id="h")
    c = reg.counter("ops_total", "ops", labels=("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    g = reg.gauge("depth")
    g.set(7)
    g.dec(2)
    h = reg.histogram("lat_seconds")
    for v in (0.0002, 0.003, 0.003, 0.2):
        h.observe(v)
    series = parse_exposition(reg.render())
    assert series["idds_ops_total"][(("head", "h"), ("kind", "a"))] == 3
    assert series["idds_ops_total"][(("head", "h"), ("kind", "b"))] == 1
    assert series["idds_depth"][(("head", "h"),)] == 5
    assert series["idds_lat_seconds_count"][(("head", "h"),)] == 4
    assert series["idds_lat_seconds_sum"][(("head", "h"),)] == \
        pytest.approx(0.2062)


def test_histogram_buckets_cumulative_and_percentiles():
    reg = MetricsRegistry(head_id="h")
    h = reg.histogram("lat").labels()
    for _ in range(90):
        h.observe(0.0009)   # <= 0.001 bucket
    for _ in range(10):
        h.observe(0.9)      # <= 1.0 bucket
    series = parse_exposition(reg.render())
    le = {dict(k)["le"]: v for k, v in series["idds_lat_bucket"].items()}
    assert le["0.001"] == 90
    assert le["1"] == 100          # cumulative
    assert le["+Inf"] == 100
    p = h.percentiles()
    assert p["p50"] <= 0.001
    assert 0.5 <= p["p99"] <= 1.0


def test_observe_many_matches_loop_of_observes():
    reg = MetricsRegistry(head_id="h")
    a = reg.histogram("one").labels()
    b = reg.histogram("bulk").labels()
    vals = [0.0002, 0.004, 0.004, 0.3, 50.0, 1e6]  # last -> +Inf bucket
    for v in vals:
        a.observe(v)
    b.observe_many(vals)
    assert a.counts == b.counts
    assert a.sum == pytest.approx(b.sum)
    assert a.count == b.count == len(vals)


def test_disabled_registry_is_noop_but_renders_empty_families():
    reg = MetricsRegistry(head_id="h", enabled=False)
    c = reg.counter("ops")
    c.inc()
    c.labels().inc(5)
    h = reg.histogram("lat")
    h.observe(1.0)
    h.labels().observe_many([1.0, 2.0])
    with h.labels().time():
        pass
    assert "idds_ops" not in parse_exposition(reg.render())


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.histogram("x")


def test_label_values_escaped_round_trip():
    reg = MetricsRegistry(head_id="h")
    reg.counter("ops", labels=("q",)).labels(q='a"b\\c').inc()
    series = parse_exposition(reg.render())
    keys = list(series["idds_ops"])
    assert any(("q", 'a\\"b\\\\c') in k or ("q", 'a"b\\c') in k
               for k in keys)


def test_timer_context_observes_positive_duration():
    reg = MetricsRegistry(head_id="h")
    h = reg.histogram("dur").labels()
    with h.time():
        time.sleep(0.002)
    assert h.count == 1
    assert h.sum >= 0.002


# ---------------------------------------------------- cluster aggregation

def test_render_snapshots_merges_heads_without_collisions():
    r1 = MetricsRegistry(head_id="head-1")
    r2 = MetricsRegistry(head_id="head-2")
    r1.counter("ops").inc(3)
    r2.counter("ops").inc(4)
    merged = parse_exposition(render_snapshots([r1.snapshot(),
                                                r2.snapshot()]))
    per_head = {dict(k)["head"]: v for k, v in merged["idds_ops"].items()}
    assert per_head == {"head-1": 3, "head-2": 4}


# ------------------------------------------------------------ build_trace

def test_build_trace_pairs_spans_and_attributes_heads():
    t0 = 1000.0
    events = [
        {"event": "submitted", "ts": t0, "head_id": "head-1",
         "trace_id": "tr-x", "entity": None},
        {"event": "workflow_started", "ts": t0 + 0.5,
         "head_id": "head-2", "trace_id": "tr-x", "entity": None},
        {"event": "job_leased", "ts": t0 + 1.0, "head_id": "head-2",
         "entity": "j1"},
        {"event": "job_completed", "ts": t0 + 3.0, "head_id": "head-2",
         "entity": "j1"},
        {"event": "job_leased", "ts": t0 + 1.5, "head_id": "head-2",
         "entity": "j2"},  # unpaired: no completion
    ]
    out = build_trace(events)
    assert out["trace_id"] == "tr-x"
    assert out["heads"] == ["head-1", "head-2"]
    spans = {s["span"]: s for s in out["spans"]}
    assert spans["marshal"]["duration_s"] == pytest.approx(0.5)
    assert spans["marshal"]["head_start"] == "head-1"
    assert spans["marshal"]["head_end"] == "head-2"
    assert spans["execute"]["entity"] == "j1"
    assert spans["execute"]["duration_s"] == pytest.approx(2.0)
    assert out["duration_s"] == pytest.approx(3.0)
    assert [e["dt_s"] for e in out["events"]] == \
        [0.0, 0.5, 1.0, 1.5, 3.0]


def test_build_trace_empty_and_unpaired_only():
    assert build_trace([]) == {"trace_id": None, "events": [],
                               "spans": [], "heads": [],
                               "duration_s": 0.0}
    out = build_trace([{"event": "job_leased", "ts": 1.0,
                        "head_id": "h", "entity": "j"}])
    assert out["spans"] == []


def test_store_write_series_ticks_on_bulk_journal_verb():
    reg = MetricsRegistry(head_id="h")
    store = InMemoryStore()
    store.bind_metrics(reg)
    store.save_many([("request", {"request_id": "r1",
                                  "status": "new"})] * 3)
    series = parse_exposition(reg.render())
    key = (("head", "h"), ("backend", "InMemoryStore"))
    assert series["idds_store_write_ops_total"][key] == 3
    assert series["idds_store_write_seconds_count"][key] == 1


# ------------------------------------------------------- service surface

def test_inline_run_trace_has_positive_spans():
    idds = IDDS(store=InMemoryStore())
    rid = idds.submit_workflow(_noop_workflow(2))
    idds.pump()
    tr = idds.trace(rid)
    assert tr["status"] == "finished"
    assert tr["spans"], tr
    assert all(s["duration_s"] >= 0.0 for s in tr["spans"])
    names = {s["span"] for s in tr["spans"]}
    assert "marshal" in names and "transform" in names
    idds.close()


def test_telemetry_off_no_trace_and_empty_metrics():
    idds = IDDS(store=InMemoryStore(), telemetry=False)
    rid = idds.submit_workflow(_noop_workflow())
    idds.pump()
    assert idds.trace(rid)["events"] == []
    assert "idds_daemon_loop_seconds_count" not in \
        parse_exposition(idds.metrics_text())
    idds.close()


def test_metrics_endpoint_over_wire_parses():
    with RestGateway(IDDS()) as gw:
        client = IDDSClient(gw.url)
        client.submit_workflow(_noop_workflow())
        gw.idds.pump()
        text = client.metrics()
        series = parse_exposition(text)
        assert sum(series["idds_rest_requests_total"].values()) >= 1
        assert sum(series["idds_daemon_loop_seconds_count"].values()) >= 1
        # bound at boot; ticks only on the bulk journal verb, which the
        # inline flow may never take — presence is the contract here
        assert "idds_store_write_ops_total" in series
        assert sum(series["idds_bus_lag_seconds_count"].values()) >= 1
        # every sample carries this head's label
        for key in series["idds_rest_requests_total"]:
            assert dict(key)["head"] == gw.idds.ctx.head_id


def test_scheduler_series_under_distributed_head():
    """The execution plane's lease/complete/job-duration histograms —
    only a --distributed head runs the JobScheduler (cluster_smoke's
    inline heads never emit these, so they are pinned here)."""
    with RestGateway(IDDS(executor=DistributedWFM(lease_ttl=5.0))) as gw:
        client = IDDSClient(gw.url)
        wf = Workflow(name="obs-dist")
        wf.add_template(WorkTemplate(name="s", payload="sleep_ms",
                                     defaults={"ms": 1}))
        wf.add_initial("s", {})
        rid = client.submit_workflow(wf)
        deadline = time.time() + 10
        job = None
        while job is None and time.time() < deadline:
            job = client.lease_job("obs-w1")
            if job is None:
                time.sleep(0.02)
        assert job is not None
        client.complete_job(job["job_id"], "obs-w1",
                            result={"ok": True, "slept_ms": 1})
        client.wait(rid, timeout=30)
        series = parse_exposition(client.metrics())
        ops = {dict(k)["op"]: v
               for k, v in series["idds_scheduler_op_seconds_count"]
               .items()}
        assert ops.get("lease", 0) >= 1
        assert ops.get("complete", 0) >= 1
        assert sum(series["idds_scheduler_job_seconds_count"]
                   .values()) >= 1


def test_stats_and_healthz_tallies_under_concurrent_mutation():
    """/v1/stats and the ~1s-cached healthz content/delivery tallies
    must stay coherent while submissions mutate the catalog from
    another thread (the cache refresh races the writers)."""
    with RestGateway(IDDS()) as gw:
        client = IDDSClient(gw.url)
        stop = threading.Event()
        errors = []

        def writer():
            w = IDDSClient(gw.url)
            try:
                while not stop.is_set():
                    w.submit_workflow(_noop_workflow())
                    gw.idds.pump()
            except Exception as e:  # noqa: BLE001 — surfaced below
                errors.append(e)

        t = threading.Thread(target=writer)
        t.start()
        try:
            last_requests = 0
            for _ in range(30):
                s = client.stats()
                h = client.healthz()
                assert h["status"] == "ok"
                assert s.get("requests", 0) >= last_requests
                last_requests = s.get("requests", 0)
                assert isinstance(h["contents"], dict)
        finally:
            stop.set()
            t.join(timeout=10)
        assert not errors, errors
        # cache expiry: a tally poll after the TTL sees the final state
        time.sleep(1.1)
        h = client.healthz()
        total = sum(h["contents"].values())
        assert total == sum(gw.idds.content_stats().values())


def test_trace_unknown_request_404_over_wire():
    with RestGateway(IDDS()) as gw:
        client = IDDSClient(gw.url)
        # the SDK maps the gateway's 404 NotFound envelope to KeyError
        with pytest.raises(KeyError):
            client.trace("req-nope")


# ------------------------------------------------------------ logging

def test_setup_logging_json_lines_and_head_tag(capsys):
    import json as _json
    import logging

    from repro.core.obs import get_logger, setup_logging
    root = setup_logging("DEBUG", json_mode=True, head_id="head-x")
    try:
        get_logger("unit").warning("something %s", "slow",
                                   extra={"daemon": "clerk",
                                          "duration_s": 1.5})
        line = capsys.readouterr().err.strip().splitlines()[-1]
        d = _json.loads(line)
        assert d["level"] == "WARNING"
        assert d["logger"] == "repro.unit"
        assert d["msg"] == "something slow"
        assert d["head"] == "head-x"
        assert d["daemon"] == "clerk"
        assert d["duration_s"] == 1.5
        # text mode: same record, [head] prefix, idempotent reconfigure
        setup_logging("INFO", json_mode=False, head_id="head-x")
        assert len(root.handlers) == 1
        get_logger("unit").info("plain")
        assert capsys.readouterr().err.strip().startswith("[head-x] ")
    finally:
        for h in list(root.handlers):
            root.removeHandler(h)
        root.setLevel(logging.WARNING)


def test_tracer_store_fault_logs_and_counts_instead_of_raising():
    faults = []

    class BrokenStore:
        def save_trace_events(self, rows):
            raise RuntimeError("disk on fire")

    from repro.core.obs import Tracer
    tr = Tracer(BrokenStore(), "head-x", on_fault=faults.append)
    tr.emit("submitted", request_id="r1")  # must not raise
    assert faults == ["submitted"]


# ----------------------------------------------------- two-head scenarios

def test_killed_head_adoption_trace_spans_both_heads():
    """Head 1 submits and starts a workflow, then dies without
    releasing its claims; head 2 adopts and finishes.  The journaled
    trace must attribute the early hops to head-1 and the finishing
    hops to head-2 — one timeline stitched across the failover."""
    store = InMemoryStore()
    ttl = 0.4
    h1 = IDDS(store=store, bus="store", head_id="head-1", claim_ttl=ttl)
    h2 = IDDS(store=store, bus="store", head_id="head-2", claim_ttl=ttl)
    rid = h1.submit_workflow(_noop_workflow(2))
    sum(d.process_once() for d in h1.daemons)  # head-1 claims + starts
    time.sleep(ttl * 1.2)  # SIGKILL semantics: claims must EXPIRE
    h2.pump_until(
        lambda: h2.request_status(rid)["status"] == "finished",
        timeout=30.0, interval=0.01)
    tr = h2.trace(rid)
    assert tr["spans"], tr
    assert all(s["duration_s"] >= 0.0 for s in tr["spans"])
    assert set(tr["heads"]) == {"head-1", "head-2"}
    h2.close()


def test_cluster_metrics_aggregates_live_peer_snapshots():
    store = InMemoryStore()
    h1 = IDDS(store=store, bus="store", head_id="head-1", claim_ttl=30.0)
    h2 = IDDS(store=store, bus="store", head_id="head-2", claim_ttl=30.0)
    h1.submit_workflow(_noop_workflow())
    h2.submit_workflow(_noop_workflow())
    h1.pump()
    h2.pump()  # first watchdog cycle heartbeats a metrics snapshot
    series = parse_exposition(h1.metrics_text(cluster=True))
    heads = {dict(k)["head"]
             for k in series["idds_bus_published_total"]}
    assert heads == {"head-1", "head-2"}
    # the local head's own series is served live, not from a snapshot
    local = parse_exposition(h1.metrics_text())
    assert {dict(k)["head"] for k in local["idds_bus_published_total"]} \
        == {"head-1"}
    h1.close()
    h2.close()
