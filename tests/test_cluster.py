"""Multi-head service: several IDDS heads pumping ONE catalog through
the store-claimed ownership plane — claim lifecycle, watchdog adoption
after a head dies mid-workflow, the pluggable bus backends, the
/v1/cluster health surface, and the /v1-only legacy-route cutover.
"""
import http.client
import json
import time

import pytest

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.spec import WorkflowSpec
from repro.core.store import InMemoryStore, SqliteStore

reg.register_payload("cluster_double",
                     lambda params, inputs: {"x": params["x"] * 2})


def _chain_workflow(x=3):
    spec = WorkflowSpec("cluster-chain")
    a = spec.work("a", payload="cluster_double", start={"x": x})
    a.then(spec.work("b", payload="cluster_double"))
    return spec.build()


@pytest.fixture(params=["memory", "sqlite"])
def shared_store(request, tmp_path):
    """Factory yielding fresh handles on ONE shared catalog, so two
    heads coordinate the way two processes would (memory shares the
    instance, sqlite the WAL file)."""
    if request.param == "memory":
        s = InMemoryStore()
        yield lambda: s
    else:
        path = str(tmp_path / "cluster.db")
        handles = []

        def make():
            h = SqliteStore(path)
            handles.append(h)
            return h

        yield make
        for h in handles:
            h.close()


# ------------------------------------------------- the tentpole scenario

def test_two_heads_kill_one_survivor_finishes_all(shared_store):
    """Two heads share one catalog over the store bus; head 1 claims
    all in-flight work and dies without releasing anything.  Once the
    claims expire, head 2's watchdog must adopt and finish every
    workflow — no request lost, none double-completed."""
    ttl = 0.5
    h1 = IDDS(store=shared_store(), bus="store", head_id="head-1",
              claim_ttl=ttl)
    h2 = IDDS(store=shared_store(), bus="store", head_id="head-2",
              claim_ttl=ttl)
    rids = [h1.submit_workflow(_chain_workflow(x=i)) for i in range(8)]
    # head 1 starts the work — one daemon cycle claims the workflows
    # and begins processing without finishing anything...
    sum(d.process_once() for d in h1.daemons)
    # ...then it is gone.  A SIGKILLed head releases nothing: the only
    # path to progress is claim EXPIRY + the peer's adoption sweep.
    time.sleep(ttl * 1.2)

    def all_done():
        return all(h2.request_status(r)["status"] == "finished"
                   for r in rids)

    h2.pump_until(all_done, timeout=60.0, interval=0.01)
    for rid in rids:
        info = h2.request_status(rid)
        assert info["status"] == "finished"
        # exactly one completion per work: a duplicated adoption replay
        # would overshoot the per-status tally
        assert info["works"] == {"finished": 2}, (rid, info)
    assert h2.stats.get("workflows_adopted", 0) == len(rids)
    # ownership converged: every surviving claim (if any) is head 2's
    for c in h2.store.list_claims("workflow"):
        assert c["owner_id"] == "head-2"


def test_two_heads_split_load_no_double_processing(shared_store):
    """Both heads pump concurrently from submission: the claim CAS
    partitions the workflows — every request finishes exactly once no
    matter which head won each claim."""
    h1 = IDDS(store=shared_store(), bus="store", head_id="head-1")
    h2 = IDDS(store=shared_store(), bus="store", head_id="head-2")
    rids = [h1.submit_workflow(_chain_workflow(x=i)) for i in range(6)]

    def all_done():
        return all(h1.request_status(r)["status"] == "finished"
                   for r in rids)

    deadline = time.monotonic() + 60.0
    while not all_done():
        moved = sum(d.process_once() for d in h1.daemons)
        moved += sum(d.process_once() for d in h2.daemons)
        if moved == 0:
            assert time.monotonic() < deadline, "cluster wedged"
            time.sleep(0.005)
    for rid in rids:
        # both heads agree on the catalog truth...
        assert {h.request_status(rid)["status"]
                for h in (h1, h2)} == {"finished"}
        # ...and whichever head(s) hydrated the DG show exactly one
        # completion per work (a double-processed work would overshoot)
        tallies = [h.request_status(rid)["works"] for h in (h1, h2)
                   if "works" in h.request_status(rid)]
        assert tallies, rid
        assert all(t == {"finished": 2} for t in tallies), (rid, tallies)


def test_clean_close_hands_claims_to_peer_immediately(shared_store):
    """idds.close() releases the head's claims, so a peer adopts the
    work on its next sweep without waiting out the TTL."""
    h1 = IDDS(store=shared_store(), bus="store", head_id="head-1",
              claim_ttl=30.0)  # TTL far beyond the test budget
    h2 = IDDS(store=shared_store(), bus="store", head_id="head-2",
              claim_ttl=30.0)
    rid = h1.submit_workflow(_chain_workflow())
    sum(d.process_once() for d in h1.daemons)
    assert any(c["owner_id"] == "head-1"
               for c in h2.store.list_claims("workflow"))
    h1.stop()
    # graceful shutdown: release claims only (don't close the shared
    # memory store under head 2)
    for wf_id in list(h1.ctx.claimed):
        h1.ctx.disown(wf_id)
    h2.pump_until(
        lambda: h2.request_status(rid)["status"] == "finished",
        timeout=60.0, interval=0.01)
    assert h2.request_status(rid)["works"] == {"finished": 2}


# --------------------------------------------------- health + ownership

def test_cluster_info_reports_heads_and_claims(shared_store):
    h1 = IDDS(store=shared_store(), bus="store", head_id="head-1")
    h2 = IDDS(store=shared_store(), bus="store", head_id="head-2")
    h1.submit_workflow(_chain_workflow())
    sum(d.process_once() for d in h1.daemons)  # heartbeat + claim
    sum(d.process_once() for d in h2.daemons)  # heartbeat only
    info = h2.cluster_info()
    assert info["head_id"] == "head-2" and info["bus"] == "store"
    heads = {h["head_id"]: h for h in info["heads"]}
    assert set(heads) == {"head-1", "head-2"}
    assert all(h["alive"] for h in heads.values())
    assert heads["head-1"]["claims"] >= 1
    assert heads["head-2"]["claims"] == 0
    assert info["claims"] >= 1
    # both heads observe the same registry
    peers = {h["head_id"] for h in h1.cluster_info()["heads"]}
    assert peers == {"head-1", "head-2"}


def test_cluster_endpoint_over_wire():
    idds = IDDS(store=InMemoryStore(), bus="store", head_id="head-rest")
    with RestGateway(idds) as gw:
        client = IDDSClient(gw.url)
        rid = client.submit_workflow(_chain_workflow())
        client.wait(rid, timeout=30)
        info = client.cluster()
        assert info["head_id"] == "head-rest"
        assert info["bus"] == "store"
        heads = {h["head_id"]: h for h in info["heads"]}
        assert heads["head-rest"]["alive"] is True
        assert heads["head-rest"]["data"].get("bus") == "store"
        # the liveness probe names the answering head + bus backend
        h = client.healthz()
        assert h["head_id"] == "head-rest" and h["bus"] == "store"
        assert h["daemons"].get("watchdog") is True


# ------------------------------------------------------- bus backends

def test_make_bus_factory_and_names():
    assert M.make_bus("local").name == "local"
    store = InMemoryStore()
    assert M.make_bus("store", store=store, head_id="h").name == "store"
    with pytest.raises(ValueError):
        M.make_bus("store")  # store backend needs a store
    with pytest.raises(ValueError):
        M.make_bus("carrier-pigeon")


def test_store_bus_queue_topic_consumed_once_cluster_wide():
    store = InMemoryStore()
    a = M.make_bus("store", store=store, head_id="A")
    b = M.make_bus("store", store=store, head_id="B")
    for i in range(4):
        a.publish(M.T_NEW_REQUESTS, {"i": i})
    got_a = a.poll(M.T_NEW_REQUESTS)
    got_b = b.poll(M.T_NEW_REQUESTS)
    # work-queue semantics: the cluster sees each message exactly once
    assert len(got_a) + len(got_b) == 4
    assert a.poll(M.T_NEW_REQUESTS) == []
    assert b.poll(M.T_NEW_REQUESTS) == []


def test_store_bus_broadcast_topic_reaches_every_head():
    store = InMemoryStore()
    a = M.make_bus("store", store=store, head_id="A")
    b = M.make_bus("store", store=store, head_id="B")
    a.publish(M.T_COLLECTION_UPDATED, {"collection": "c"})
    got_a = a.poll(M.T_COLLECTION_UPDATED)
    got_b = b.poll(M.T_COLLECTION_UPDATED)
    # broadcast semantics: every head observes the announcement once
    assert [m.body["collection"] for m in got_a] == ["c"]
    assert [m.body["collection"] for m in got_b] == ["c"]
    assert a.poll(M.T_COLLECTION_UPDATED) == []  # cursor advanced


def test_store_bus_requeue_backoff_then_redelivery():
    store = InMemoryStore()
    bus = M.make_bus("store", store=store, head_id="A")
    bus.publish(M.T_NEW_WORKS, {"k": 1})
    (m,) = bus.poll(M.T_NEW_WORKS)
    bus.requeue(m)
    # the requeued row hides behind not_before (no busy-spin) ...
    deadline = time.monotonic() + 5.0
    redelivered = []
    while not redelivered and time.monotonic() < deadline:
        redelivered = bus.poll(M.T_NEW_WORKS)
        time.sleep(0.01)
    # ... then comes back exactly once
    assert [m2.body for m2 in redelivered] == [{"k": 1}]
    assert bus.poll(M.T_NEW_WORKS) == []


# ------------------------------------------------- /v1-only API cutover

def test_legacy_routes_off_410_with_successor_pointer():
    with RestGateway(IDDS(), legacy_routes="off") as gw:
        conn = http.client.HTTPConnection(gw.host, gw.port, timeout=5)

        def get(path):
            conn.request("GET", path)
            r = conn.getresponse()
            return r, json.loads(r.read())

        r, body = get("/stats")
        assert r.status == 410
        assert body["error"]["type"] == "Gone"
        assert body["error"]["successor"] == "/v1/stats"
        assert 'rel="successor-version"' in r.getheader("Link", "")
        # POST aliases are retired too
        conn.request("POST", "/requests", body=b"{}")
        r = conn.getresponse()
        assert r.status == 410
        assert json.loads(r.read())["error"]["successor"] \
            == "/v1/requests"
        # /healthz is a probe endpoint: exempt from the cutover
        r, body = get("/healthz")
        assert r.status == 200 and body["status"] == "ok"
        # the canonical surface is untouched
        r, body = get("/v1/stats")
        assert r.status == 200
        conn.close()


def test_legacy_routes_warn_is_default_and_still_serves():
    with RestGateway(IDDS()) as gw:
        assert gw.legacy_routes == "warn"
        conn = http.client.HTTPConnection(gw.host, gw.port, timeout=5)
        conn.request("GET", "/stats")
        r = conn.getresponse()
        assert r.status == 200
        assert r.getheader("Deprecation") == "true"
        json.loads(r.read())
        conn.close()


def test_rest_gateway_rejects_bad_legacy_mode():
    with pytest.raises(ValueError):
        RestGateway(IDDS(), legacy_routes="maybe")
