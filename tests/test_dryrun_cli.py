"""Dry-run CLI smoke: run ONE cheap cell in a subprocess (the 512-device
XLA override must live in its own process) and validate the output
contract: lower+compile OK, roofline terms present and positive."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    out = tmp_path / "cell.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "train_4k",
         "--out", str(out)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    cells = json.loads(out.read_text())
    assert len(cells) == 1
    c = cells[0]
    assert c["status"] == "ok"
    assert c["chips"] == 256
    assert c["hlo_flops"] > 0 and c["hlo_bytes"] > 0
    assert c["collective_total"] > 0  # sharded train step must communicate
    rf = c["roofline"]
    assert rf["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert 0 < rf["useful_flops_ratio"] < 1.5


@pytest.mark.slow
def test_dryrun_skip_cell(tmp_path):
    """long_500k on a pure-attention arch is a DOCUMENTED skip."""
    out = tmp_path / "skip.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "yi-6b", "--shape", "long_500k", "--out", str(out)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=240)
    assert r.returncode == 0
    cells = json.loads(out.read_text())
    assert cells[0]["status"] == "skipped"
    assert "sub-quadratic" in cells[0]["reason"]
