"""REST gateway + client SDK: auth, error envelopes, concurrency, and
end-to-end workflow completion over the wire (paper §2's Restful boundary).
"""
import http.client
import json
import threading

import pytest

from repro.core import payloads as reg
from repro.core.client import IDDSClient, IDDSClientError
from repro.core.idds import IDDS, AuthError
from repro.core.requests import Request
from repro.core.rest import RestGateway
from repro.core.workflow import (Branch, Condition, FileRef, Workflow,
                                 WorkTemplate)

reg.register_payload("rest_double",
                     lambda params, inputs: {"x": params["x"] * 2})


def _chain_workflow(x=3) -> Workflow:
    wf = Workflow(name="rest-chain")
    wf.add_template(WorkTemplate(name="a", payload="rest_double"))
    wf.add_template(WorkTemplate(name="b", payload="rest_double"))
    wf.add_condition(Condition(trigger="a", true_next=[Branch("b")]))
    wf.add_initial("a", {"x": x})
    return wf


@pytest.fixture
def gateway():
    gw = RestGateway(IDDS())
    gw.start()
    yield gw
    gw.stop()


@pytest.fixture
def auth_gateway():
    gw = RestGateway(IDDS(tokens={"s3cret"}))
    gw.start()
    yield gw
    gw.stop()


# ----------------------------------------------------------------- basics

def test_healthz_no_auth(auth_gateway):
    client = IDDSClient(auth_gateway.url)  # no token on purpose
    h = client.healthz()
    assert h["status"] == "ok"
    assert set(h["daemons"]) == {"clerk", "marshaller", "commander",
                                 "transformer", "carrier", "conductor",
                                 "publisher", "watchdog"}
    # head identity + bus backend: which cluster member answered
    assert h["head_id"] == auth_gateway.idds.ctx.head_id
    assert h["bus"] == "local"


def test_healthz_alias_parity(auth_gateway):
    """/healthz is a thin alias of the canonical /v1/healthz: same
    handler, so the payloads agree key-for-key (uptime may tick)."""
    conn = http.client.HTTPConnection(auth_gateway.host,
                                      auth_gateway.port, timeout=5)

    def get(path):
        conn.request("GET", path)
        r = conn.getresponse()
        return json.loads(r.read())

    canon, alias = get("/v1/healthz"), get("/healthz")
    conn.close()
    canon.pop("uptime_s"), alias.pop("uptime_s")
    assert canon == alias


def test_end_to_end_workflow(gateway):
    client = IDDSClient(gateway.url)
    rid = client.submit_workflow(_chain_workflow(), requester="alice")
    info = client.wait(rid, timeout=30)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 2}
    wf = client.get_workflow(rid)
    assert sorted(w.result["x"] for w in wf.works.values()) == [6, 6]
    assert client.stats()["requests"] >= 1


def test_collection_lookup_over_wire(gateway):
    gateway.idds.ctx.ddm.register_collection(
        "data/raw.2026", [FileRef("f0", size=10, available=True),
                          FileRef("f1", size=20)])
    client = IDDSClient(gateway.url)
    coll = client.lookup_collection("data/raw.2026")
    assert coll["name"] == "data/raw.2026"
    contents = client.lookup_contents("data/raw.2026")
    assert [f["name"] for f in contents] == ["f0", "f1"]
    assert [f["available"] for f in contents] == [True, False]


def test_unknown_request_is_404(gateway):
    client = IDDSClient(gateway.url)
    with pytest.raises(KeyError):
        client.status("req-nonexistent")
    with pytest.raises(KeyError):
        client.get_workflow("req-nonexistent")


def test_unknown_route_and_method(gateway):
    conn = http.client.HTTPConnection(gateway.host, gateway.port, timeout=5)
    conn.request("GET", "/nope")
    r = conn.getresponse()
    assert r.status == 404
    assert json.loads(r.read())["error"]["type"] == "NotFound"
    conn.request("POST", "/stats", body=b"{}")
    r = conn.getresponse()
    assert r.status == 405
    # known path + wrong method: the Allow header lists what works
    assert r.getheader("Allow") == "GET"
    r.read()
    conn.request("DELETE", "/v1/requests")
    r = conn.getresponse()
    assert r.status == 405
    assert r.getheader("Allow") == "GET, POST"
    conn.close()


def test_legacy_alias_deprecation_header(gateway):
    """Unversioned paths still serve, marked deprecated; /v1 is clean."""
    conn = http.client.HTTPConnection(gateway.host, gateway.port, timeout=5)
    conn.request("GET", "/stats")
    r = conn.getresponse()
    assert r.status == 200
    assert r.getheader("Deprecation") == "true"
    assert 'rel="successor-version"' in r.getheader("Link", "")
    r.read()
    conn.request("GET", "/v1/stats")
    r = conn.getresponse()
    assert r.status == 200
    assert r.getheader("Deprecation") is None
    conn.close()


# ------------------------------------------------------------------- auth

def test_auth_failure_on_submit(auth_gateway):
    client = IDDSClient(auth_gateway.url, token="wrong")
    with pytest.raises(AuthError):
        client.submit_workflow(_chain_workflow())


def test_auth_failure_on_status(auth_gateway):
    good = IDDSClient(auth_gateway.url, token="s3cret")
    rid = good.submit_workflow(_chain_workflow())
    bad = IDDSClient(auth_gateway.url)
    with pytest.raises(AuthError):
        bad.status(rid)
    with pytest.raises(AuthError):
        bad.stats()


def test_auth_success_end_to_end(auth_gateway):
    client = IDDSClient(auth_gateway.url, token="s3cret")
    rid = client.submit_workflow(_chain_workflow())
    info = client.wait(rid, timeout=30)
    assert info["works"] == {"finished": 2}


def test_body_token_also_accepted(auth_gateway):
    """The Request body can carry the token (in-process parity)."""
    client = IDDSClient(auth_gateway.url)  # no header token
    req = Request(workflow=_chain_workflow(), token="s3cret")
    rid = client.submit(req.to_json())
    assert rid == req.request_id


# ----------------------------------------------------------- bad payloads

def test_bad_json_is_400(gateway):
    conn = http.client.HTTPConnection(gateway.host, gateway.port, timeout=5)
    conn.request("POST", "/requests", body=b"{not json!",
                 headers={"Content-Type": "application/json"})
    r = conn.getresponse()
    assert r.status == 400
    env = json.loads(r.read())["error"]
    assert env["type"] == "BadRequest"
    assert "JSON" in env["message"]
    conn.close()


def test_non_request_json_is_400(gateway):
    conn = http.client.HTTPConnection(gateway.host, gateway.port, timeout=5)
    for body in (b"[1, 2, 3]", b'{"no": "workflow"}'):
        conn.request("POST", "/requests", body=body)
        r = conn.getresponse()
        assert r.status == 400
        assert json.loads(r.read())["error"]["type"] == "BadRequest"
    conn.close()


def test_client_error_no_retry_on_4xx(gateway):
    client = IDDSClient(gateway.url, retries=3, backoff=5.0)
    # a 400 must raise immediately — a retried 400 would sleep 5s+ here
    with pytest.raises(IDDSClientError) as ei:
        client._post("/requests", {"no": "workflow"})
    assert ei.value.status == 400


# ---------------------------------------------------- robustness regressions

def test_duplicate_submit_is_idempotent(gateway):
    """A client retry after a lost response must not run the workflow
    twice (server dedups on the client-generated request_id)."""
    client = IDDSClient(gateway.url)
    req_json = Request(workflow=_chain_workflow()).to_json()
    rid1 = client.submit(req_json)
    rid2 = client.submit(req_json)  # simulated retry
    assert rid1 == rid2
    info = client.wait(rid1, timeout=30)
    assert info["works"] == {"finished": 2}  # not 4
    assert gateway.idds.stats["requests"] == 1


def test_keepalive_survives_bodied_request_to_get_route(gateway):
    """A 405 reply must drain the unread body, or the next request on the
    same keep-alive connection is parsed mid-body."""
    conn = http.client.HTTPConnection(gateway.host, gateway.port, timeout=5)
    conn.request("POST", "/stats", body=b'{"k": 1}')
    r = conn.getresponse()
    assert r.status == 405
    r.read()
    conn.request("GET", "/healthz")  # same connection
    r = conn.getresponse()
    assert r.status == 200
    assert json.loads(r.read())["status"] == "ok"
    conn.close()


def test_unregistered_predicate_does_not_wedge_status(gateway):
    """A raising predicate must not leak the in-flight counter and pin
    the request at 'running' forever."""
    wf = Workflow(name="bad-predicate")
    wf.add_template(WorkTemplate(name="a", payload="rest_double"))
    wf.add_template(WorkTemplate(name="b", payload="rest_double"))
    wf.add_condition(Condition(trigger="a", predicate="not-registered",
                               true_next=[Branch("b")]))
    wf.add_initial("a", {"x": 1})
    client = IDDSClient(gateway.url)
    rid = client.submit_workflow(wf)
    info = client.wait(rid, timeout=30)  # would TimeoutError if wedged
    assert info["works"] == {"finished": 1}  # condition eval failed -> no b


# ------------------------------------------------- bulk content transition

def test_contents_transition_over_wire(gateway):
    gateway.idds.ctx.ddm.register_collection(
        "data/bulk", [FileRef("f0", size=10),
                      FileRef("f1", size=20, available=True)])
    client = IDDSClient(gateway.url)
    out = client.transition_contents("data/bulk", [
        {"name": "f0", "status": "staging"},
        {"name": "f1", "status": "delivered"},
        {"name": "f2", "status": "new", "size": 5},  # register-on-the-fly
    ])
    assert out["applied"] == 3 and out["skipped"] == 0
    assert all(r["applied"] for r in out["results"])
    contents = client.lookup_contents("data/bulk")
    by_name = {f["name"]: f for f in contents}
    assert by_name["f0"]["status"] == "staging"
    assert by_name["f1"]["status"] == "delivered"
    assert by_name["f1"]["processed"] is True
    assert by_name["f2"]["size"] == 5 and by_name["f2"]["status"] == "new"


def test_contents_transition_rank_guard_reports_skips(gateway):
    """A backward transition is skipped (not an error) and the response
    reports the file's live status, so a replayed batch is a no-op."""
    gateway.idds.ctx.ddm.register_collection(
        "data/guard", [FileRef("g0", size=1, available=True)])
    client = IDDSClient(gateway.url)
    out = client.transition_contents(
        "data/guard", [{"name": "g0", "status": "staging"}])
    assert out["applied"] == 0 and out["skipped"] == 1
    (r,) = out["results"]
    assert r["applied"] is False and r["status"] == "available"
    # forward transitions still apply after the skip
    out = client.transition_contents(
        "data/guard", [{"name": "g0", "status": "delivered"}])
    assert out["applied"] == 1


def test_contents_transition_validation_envelopes(gateway):
    conn = http.client.HTTPConnection(gateway.host, gateway.port,
                                      timeout=5)

    def post(path, body):
        conn.request("POST", path, body=json.dumps(body).encode())
        r = conn.getresponse()
        return r.status, json.loads(r.read())

    path = "/v1/collections/data%2Fx/contents:transition"
    for body in ({}, {"transitions": []},
                 {"transitions": [{"name": "f"}]},
                 {"transitions": [{"name": "f", "status": "bogus"}]},
                 {"transitions": ["not-a-dict"]}):
        status, env = post(path, body)
        assert status == 400, body
        assert env["error"]["type"] == "BadRequest", body
    conn.close()


def test_contents_transition_unknown_collection_404(gateway):
    """With a DDM that does not auto-create collections, transitioning
    an unknown collection is a 404 envelope."""
    real_get = gateway.idds.ctx.ddm.get_collection

    def strict_get(name):
        raise KeyError(name)

    gateway.idds.ctx.ddm.get_collection = strict_get
    try:
        client = IDDSClient(gateway.url)
        with pytest.raises(KeyError):
            client.transition_contents(
                "no/such", [{"name": "f", "status": "new"}])
    finally:
        gateway.idds.ctx.ddm.get_collection = real_get


# ------------------------------------------------------------ concurrency

def test_concurrent_submissions(gateway):
    n_clients, per_client = 8, 5
    results, errors = [], []

    def one_client(i):
        try:
            client = IDDSClient(gateway.url)
            rids = [client.submit_workflow(_chain_workflow(x=i))
                    for _ in range(per_client)]
            for rid in rids:
                info = client.wait(rid, timeout=60)
                results.append(info["works"])
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=one_client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert len(results) == n_clients * per_client
    assert all(r == {"finished": 2} for r in results)
    assert gateway.idds.stats["requests"] == n_clients * per_client
