"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis
property tests (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # No hypothesis on this machine: the property tests skip but the
    # parametrized sweeps below must still collect and run.  The stubs
    # keep the module-level @given/@settings/st.* expressions valid.
    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.kernels.cross_entropy import cross_entropy_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import (attention_naive, cross_entropy_direct_ref,
                               cross_entropy_blockwise_ref,
                               flash_attention_ref, rmsnorm_ref,
                               ssd_decode_ref, ssd_ref, ssd_sequential_ref)
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_pallas

jax.config.update("jax_default_matmul_precision", "highest")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# ------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(8, 128), (3, 7, 384), (1, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype)
    a = rmsnorm_pallas(x, w, block_rows=4)
    b = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 64), d=st.integers(8, 256),
       seed=st.integers(0, 2**30))
def test_rmsnorm_property(rows, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d), jnp.float32)
    w = jnp.ones((d,), jnp.float32)
    y = rmsnorm_pallas(x, w, block_rows=16)
    # invariant: output row RMS == 1 (up to eps)
    rms = np.sqrt(np.mean(np.asarray(y, np.float32) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------- flash attention

CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, q_off, kv_len
    (2, 128, 128, 4, 2, 64, True, 0, 0, None),
    (1, 100, 160, 6, 6, 64, True, 0, 0, None),      # whisper-ish heads
    (2, 1, 256, 8, 2, 128, True, 0, 200, 201),      # decode
    (2, 64, 256, 4, 4, 64, True, 48, 0, None),      # sliding window
    (1, 96, 160, 4, 2, 64, False, 0, 0, None),      # cross attention
    (1, 80, 80, 40, 40, 32, True, 0, 0, None),      # qwen32b head count
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_sweep(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, sw, qoff, kvl = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    a = flash_attention_pallas(q, k, v, causal=causal, sliding_window=sw,
                               q_offset=qoff, kv_len=kvl,
                               block_q=32, block_k=64)
    b = attention_naive(q, k, v, causal=causal, sliding_window=sw,
                        q_offset=qoff, kv_len=kvl)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_tol(dtype))


@pytest.mark.parametrize("case", CASES)
def test_flash_ref_matches_naive(case):
    B, Sq, Sk, Hq, Hkv, D, causal, sw, qoff, kvl = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), jnp.float32)
    a = flash_attention_ref(q, k, v, causal=causal, sliding_window=sw,
                            q_offset=qoff, kv_len=kvl, block_k=48)
    b = attention_naive(q, k, v, causal=causal, sliding_window=sw,
                        q_offset=qoff, kv_len=kvl)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_flash_ref_custom_vjp_matches_autodiff_oracle():
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (2, 40, 8, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 32), jnp.float32)
    do = jax.random.normal(ks[3], (2, 40, 8, 32), jnp.float32)
    f = lambda *a: jnp.vdot(flash_attention_ref(*a, block_k=16), do)
    g = lambda *a: jnp.vdot(attention_naive(*a), do)
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(sq=st.integers(1, 80), sk=st.integers(8, 96),
       hq=st.sampled_from([2, 4, 6]), g=st.sampled_from([1, 2]),
       seed=st.integers(0, 2**30))
def test_flash_pallas_property(sq, sk, hq, g, seed):
    """Property: pallas flash == naive attention on random shapes."""
    if hq % g:
        g = 1
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, hq, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, sk, hq // g, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, sk, hq // g, 32), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=False, block_q=16,
                               block_k=32)
    b = attention_naive(q, k, v, causal=False)
    np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)


# ----------------------------------------------------------------- SSD

SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 96, 4, 16, 1, 32, 32),
    (1, 130, 6, 32, 2, 16, 64),   # ragged tail
    (2, 64, 2, 64, 1, 128, 32),   # mamba2-130m-like dims
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_sweep(case, dtype):
    B, S, H, P, G, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = (jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
         * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, G, N), jnp.float32)
          * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, G, N), jnp.float32)
          * 0.3).astype(dtype)
    y1 = ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk)
    y2, _ = ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 3e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 3e-4)


def test_ssd_ref_chunk_invariance():
    """Property: chunk size must not change the result (SSD identity)."""
    B, S, H, P, G, N = 2, 120, 4, 16, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N), jnp.float32) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N), jnp.float32) * 0.3
    outs = [ssd_ref(x, dt, A, Bm, Cm, chunk=c) for c in (16, 40, 120)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)


def test_ssd_state_chaining_equals_decode():
    """Prefill state + single-token decode == one longer prefill."""
    B, S, H, P, G, N = 1, 33, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N), jnp.float32) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N), jnp.float32) * 0.3
    y_full, _ = ssd_ref(x, dt, A, Bm, Cm, chunk=16, return_state=True)
    _, h = ssd_ref(x[:, :-1], dt[:, :-1], A, Bm[:, :-1], Cm[:, :-1],
                   chunk=16, return_state=True)
    y_dec, _ = ssd_decode_ref(x[:, -1], dt[:, -1], A, Bm[:, -1], Cm[:, -1],
                              h)
    np.testing.assert_allclose(y_full[:, -1], y_dec, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(2, 70), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**30))
def test_ssd_pallas_property(s, chunk, seed):
    B, H, P, G, N = 1, 2, 8, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, s, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, s, G, N), jnp.float32) * 0.3
    Cm = jax.random.normal(ks[4], (B, s, G, N), jnp.float32) * 0.3
    y1, h1 = ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, return_state=True)
    y2, h2 = ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h1, h2, rtol=5e-4, atol=5e-4)


# ------------------------------------------------------------------ CE

@pytest.mark.parametrize("T,D,V,bt,bv", [
    (100, 64, 1000, 32, 256), (256, 128, 511, 64, 128), (64, 32, 50, 16, 16)])
def test_ce_pallas_sweep(T, D, V, bt, bv):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (T, D), jnp.float32)
    w = jax.random.normal(ks[1], (V, D), jnp.float32) * 0.05
    t = jax.random.randint(ks[2], (T,), 0, V, jnp.int32)
    valid = (jnp.arange(T) % 3 != 0).astype(jnp.float32)
    a = cross_entropy_pallas(h, w, t, valid, block_t=bt, block_v=bv)
    b = cross_entropy_direct_ref(h, w, t, valid)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(2, 80), v=st.integers(3, 300),
       seed=st.integers(0, 2**30))
def test_ce_blockwise_property(t, v, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = jax.random.normal(ks[0], (t, 16), jnp.float32)
    w = jax.random.normal(ks[1], (v, 16), jnp.float32) * 0.1
    tg = jax.random.randint(ks[2], (t,), 0, v, jnp.int32)
    a = cross_entropy_blockwise_ref(h, w, tg, block_v=32)
    b = cross_entropy_direct_ref(h, w, tg)
    np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)
    # property: NLL >= 0 and >= log(1) trivially; also finite
    assert np.isfinite(float(a))
