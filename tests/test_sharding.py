"""Sharding rules: logical resolution, divisibility fallbacks, dedup."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec

from repro.models.params import pdef
from repro.sharding import ShardingRules, param_specs


@pytest.fixture(scope="module")
def mesh():
    # 1 real device; mesh shape (1, 1) keeps axis NAMES resolvable
    return jax.make_mesh((1, 1), ("data", "model"))


def rules(mesh, model=16, data=16):
    """Fake axis sizes for resolution tests via a stub mesh-shape view."""
    r = ShardingRules(mesh)
    r.mesh = type("M", (), {"shape": {"data": data, "model": model}})()
    return r


def test_divisible_dims_shard(mesh):
    r = rules(mesh)
    assert r.spec(("embed", "ffn"), (4096, 11008)) == \
        PartitionSpec("data", "model")


def test_non_divisible_falls_back_to_replicated(mesh):
    r = rules(mesh)
    # 40 heads % 16 != 0 -> replicated
    assert r.spec(("heads",), (40,)) == PartitionSpec(None)
    # 6 heads (whisper)
    assert r.spec(("heads",), (6,)) == PartitionSpec(None)


def test_batch_uses_pod_and_data_axes(mesh):
    r = ShardingRules(mesh)
    r.mesh = type("M", (), {"shape": {"pod": 2, "data": 16, "model": 16}})()
    assert r.spec(("batch", None), (256, 128)) == \
        PartitionSpec(("pod", "data"), None)


def test_batch_prefix_fallback(mesh):
    """batch=1 (long_500k): falls back through prefixes to replicated."""
    r = ShardingRules(mesh)
    r.mesh = type("M", (), {"shape": {"pod": 2, "data": 16, "model": 16}})()
    assert r.spec(("batch",), (1,)) == PartitionSpec(None)
    # batch=2: divisible by pod prefix only
    assert r.spec(("batch",), (2,)) == PartitionSpec("pod")


def test_duplicate_axis_dedup(mesh):
    """MoE weights tag both 'expert' and 'ffn' -> model axis used once."""
    r = rules(mesh)
    # qwen3: 128 experts divide -> expert wins, ffn dropped
    assert r.spec(("layers", "expert", "embed", "ffn"),
                  (94, 128, 4096, 1536)) == \
        PartitionSpec(None, "model", "data", None)
    # mixtral: 8 experts don't divide -> ffn gets the model axis
    assert r.spec(("layers", "expert", "embed", "ffn"),
                  (32, 8, 4096, 14336)) == \
        PartitionSpec(None, None, "data", "model")


def test_kv_cache_dedup_kvseq_over_heads(mesh):
    r = rules(mesh)
    spec = r.spec(("layers", "batch", "kv_seq", "heads", None),
                  (38, 128, 32768, 32, 64))
    # kv_seq claims the model axis first; heads dropped
    assert spec == PartitionSpec(None, "data", "model", None, None)


def test_param_specs_tree(mesh):
    r = rules(mesh)
    defs = {"w": pdef((4096, 1024), ("embed", "qkv")),
            "b": pdef((1024,), ("qkv",))}
    specs = param_specs(defs, r)
    assert specs["w"] == PartitionSpec("data", "model")
    assert specs["b"] == PartitionSpec("model")


def test_vocab_fallback_on_odd_vocab(mesh):
    r = rules(mesh)
    # mamba2 vocab 50280 % 16 != 0 -> replicated
    assert r.spec(("vocab", "embed"), (50280, 768)) == \
        PartitionSpec(None, "data")
    assert r.spec(("vocab", "embed"), (152064, 5120)) == \
        PartitionSpec("model", "data")


def test_constrain_noop_without_context():
    from repro.sharding import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x
