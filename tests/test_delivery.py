"""Content delivery plane: per-file Content records journaled on both
store backends, the CarouselDDM mounted as the head's DDM (incremental
per-file dispatch driven by Stager announcements), the Conductor's
subscription/delivery tracking with retries + acks, the /v1 REST surface
(collections, contents, subscriptions), and kill-and-recover semantics.
"""
import threading
import time

import numpy as np
import pytest

from repro.carousel.ddm import CarouselDDM
from repro.carousel.storage import ColdStore, DiskCache, TapeFile
from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.client import IDDSClient, IDDSClientError
from repro.core.daemons import Conductor
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.spec import WorkflowSpec
from repro.core.store import InMemoryStore, SqliteStore
from repro.core.workflow import FileRef
from repro.worker import WorkerAgent


@pytest.fixture(autouse=True)
def _payloads():
    reg.register_payload("dl_echo", lambda params, inputs: {
        "inputs": list(inputs)})
    yield


def _mk_cold(n=4, rows=4):
    cold = ColdStore(drives=2)
    for i in range(n):
        cold.add(TapeFile(f"f{i}", size=10, payload={
            "x": np.arange(rows * 2).reshape(rows, 2)}))
    return cold


def _carousel_workflow(name="carousel", coll="tape", out="out.tape"):
    spec = WorkflowSpec(name)
    spec.work("proc", payload="dl_echo", input_collection=coll,
              output_collection=out, granularity="fine", start={})
    return spec.build()


def _conductor(idds):
    return next(d for d in idds.daemons if isinstance(d, Conductor))


def _store_factory(kind, tmp_path):
    if kind == "memory":
        store = InMemoryStore()
        return lambda: store  # same object survives the "crash"
    path = str(tmp_path / "state.db")
    return lambda: SqliteStore(path)


# -------------------------------------------------- content state machine

@pytest.mark.parametrize("kind", ["memory", "sqlite"])
def test_content_state_machine_journaled(kind, tmp_path):
    """new -> staging -> available -> delivered transitions (plus a
    terminal failed) are journaled through the store as they happen."""
    mk = _store_factory(kind, tmp_path)
    cold = _mk_cold(3)
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    idds = IDDS(ddm=ddm, store=mk())
    ddm.register_from_cold("tape")

    def stored():
        (coll,) = [c for c in idds.store.load_collections()
                   if c["name"] == "tape"]
        return {f["name"]: f["status"] for f in coll["files"]}

    assert stored() == {"f0": "new", "f1": "new", "f2": "new"}
    ddm.mark_staging("tape", "f0")
    assert stored()["f0"] == "staging"
    ddm.set_available("tape", "f0")
    assert stored()["f0"] == "available"
    ddm.set_failed("tape", "f1")
    assert stored()["f1"] == "failed"
    ddm.set_available("tape", "f2")
    ddm.cache.put("f2", {"x": np.zeros((1, 1))}, 10, pin=False)
    ddm.mark_processed("tape", "f2")
    assert stored()["f2"] == "delivered"
    # the rank guard: a stale lower-rank write cannot regress the row
    idds.store.save_contents("tape", [
        FileRef("f2", available=True, status="available").to_dict()])
    assert stored()["f2"] == "delivered"
    idds.close()


def test_carousel_mounted_incremental_dispatch():
    """The tentpole wiring, in-process: a file-backed collection staged
    through a mounted CarouselDDM dispatches per-file processings as
    shards land (Stager announcements -> Transformer), and every content
    row ends delivered."""
    cold = _mk_cold(4)
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    idds = IDDS(ddm=ddm)
    ddm.register_from_cold("tape")
    rid = idds.submit_workflow(_carousel_workflow())
    idds.pump()
    assert idds.stats.get("processings_created", 0) == 0  # nothing staged
    st = ddm.stage_collection("tape", workers=2)
    idds.pump_until(
        lambda: idds.request_status(rid)["status"] == "finished",
        timeout=30, interval=0.005)
    procs = list(idds.ctx.processings.values())
    assert len(procs) == 4  # one per file — fine granularity
    assert sorted(f for p in procs for f in p.input_files) == [
        "f0", "f1", "f2", "f3"]
    assert all(len(p.input_files) == 1 for p in procs)
    assert [f["status"] for f in idds.lookup_contents("tape")] == \
        ["delivered"] * 4
    # prompt release: the staged bytes were freed as files were consumed
    assert ddm.cache.stats()["entries"] == 0
    st.shutdown()
    idds.close()


def test_failed_staging_surfaces_as_subfinished():
    """A shard whose staging fails terminally must not wedge the work:
    it finalizes subfinished with the failed content row terminal."""
    cold = _mk_cold(3)
    real_read = cold.read
    cold.read = lambda name: (_ for _ in ()).throw(
        IOError("tape")) if name == "f1" else real_read(name)
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    idds = IDDS(ddm=ddm)
    ddm.register_from_cold("tape")
    rid = idds.submit_workflow(_carousel_workflow())
    idds.pump()
    st = ddm.stage_collection("tape", workers=2, max_attempts=2,
                              backoff=0.001)
    idds.pump_until(
        lambda: idds.request_status(rid)["status"] == "finished",
        timeout=30, interval=0.005)
    assert idds.request_status(rid)["works"] == {"subfinished": 1}
    statuses = {f["name"]: f["status"]
                for f in idds.lookup_contents("tape")}
    assert statuses == {"f0": "delivered", "f1": "failed",
                        "f2": "delivered"}
    st.shutdown()
    idds.close()


# ------------------------------------------------ Conductor delivery plane

def test_conductor_matches_subscriptions_and_acks():
    idds = IDDS()
    sub = idds.subscribe("trainer", ["out.*"])
    other = idds.subscribe("dashboard")          # match-all
    rid = idds.submit_workflow(_carousel_workflow(coll=None or "tape",
                                                  out="out.tape"))
    idds.ctx.ddm.register_collection(
        "tape", [FileRef("f0", size=1, available=True)])
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"
    # one output content -> one delivery per matching subscription
    dels = idds.list_deliveries(sub["sub_id"])
    assert dels["total"] == 1
    (d,) = dels["deliveries"]
    assert d["status"] == "notified" and d["collection"] == "out.tape"
    assert idds.list_deliveries(other["sub_id"])["total"] == 1
    # output content registered + available in the DDM
    (out,) = idds.lookup_contents("out.tape")
    assert out["available"] and out["status"] == "available"
    # ack from ONE subscription: content not yet delivered
    r = idds.ack_delivery(sub["sub_id"], [d["delivery_id"]])
    assert r["acked"] == 1
    (out,) = idds.lookup_contents("out.tape")
    assert out["status"] == "available"
    # ack from the other: now every subscriber confirmed -> delivered
    (d2,) = idds.list_deliveries(other["sub_id"])["deliveries"]
    idds.ack_delivery(other["sub_id"], [d2["delivery_id"]])
    (out,) = idds.lookup_contents("out.tape")
    assert out["status"] == "delivered"
    # acking again is idempotent
    assert idds.ack_delivery(sub["sub_id"],
                             [d["delivery_id"]])["acked"] == 0
    stats = idds.delivery_stats()
    assert stats["subscriptions"] == 2 and stats["acked"] == 2
    idds.close()


def test_conductor_retries_then_fails_unacked():
    idds = IDDS()
    cond = _conductor(idds)
    cond.retry_interval = 0.0       # every pump round is "overdue"
    cond.max_notify_attempts = 3
    sub = idds.subscribe("slow-consumer", ["out.tape"])
    idds.ctx.ddm.register_collection(
        "tape", [FileRef("f0", size=1, available=True)])
    idds.submit_workflow(_carousel_workflow())
    idds.pump()   # quiesces only once the delivery went terminal
    (d,) = idds.list_deliveries(sub["sub_id"])["deliveries"]
    assert d["status"] == "failed"
    assert d["attempts"] == 3
    assert idds.stats["delivery_retries"] == 2
    assert idds.stats["deliveries_failed"] == 1
    # the failed delivery is journaled
    (row,) = idds.store.load_subscriptions()
    assert [v["status"] for v in row["deliveries"].values()] == ["failed"]
    idds.close()


def test_ack_batch_with_bad_id_mutates_nothing():
    """A batch containing one unknown delivery id must 404 without
    half-acking the valid ids — a corrected retry then acks them and
    the content still turns delivered."""
    idds = IDDS()
    sub = idds.subscribe("trainer", ["out.tape"])
    idds.ctx.ddm.register_collection(
        "tape", [FileRef("f0", size=1, available=True)])
    idds.submit_workflow(_carousel_workflow())
    idds.pump()
    (d,) = idds.list_deliveries(sub["sub_id"])["deliveries"]
    with pytest.raises(KeyError):
        idds.ack_delivery(sub["sub_id"], [d["delivery_id"], "dlv-nope"])
    (d2,) = idds.list_deliveries(sub["sub_id"])["deliveries"]
    assert d2["status"] == "notified"  # nothing half-applied
    assert idds.ack_delivery(sub["sub_id"],
                             [d["delivery_id"]])["acked"] == 1
    (out,) = idds.lookup_contents("out.tape")
    assert out["status"] == "delivered"
    idds.close()


def test_coarse_partial_staging_failure_dispatches_survivors():
    """A coarse work whose collection has a terminally-failed shard must
    dispatch the survivors once everything is terminal — subfinished,
    not wedged forever."""
    cold = _mk_cold(3)
    real_read = cold.read
    cold.read = lambda name: (_ for _ in ()).throw(
        IOError("tape")) if name == "f1" else real_read(name)
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    idds = IDDS(ddm=ddm)
    ddm.register_from_cold("tape")
    spec = WorkflowSpec("coarse")
    spec.work("proc", payload="dl_echo", input_collection="tape",
              granularity="coarse", start={})
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    st = ddm.stage_collection("tape", workers=2, max_attempts=2,
                              backoff=0.001)
    idds.pump_until(
        lambda: idds.request_status(rid)["status"] == "finished",
        timeout=30, interval=0.005)
    assert idds.request_status(rid)["works"] == {"subfinished": 1}
    (proc,) = idds.ctx.processings.values()
    assert sorted(proc.input_files) == ["f0", "f2"]
    st.shutdown()
    idds.close()


def test_coarse_all_failed_staging_finalizes():
    cold = _mk_cold(2)
    cold.read = lambda name: (_ for _ in ()).throw(IOError("tape"))
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    idds = IDDS(ddm=ddm)
    ddm.register_from_cold("tape")
    spec = WorkflowSpec("coarse-dead")
    spec.work("proc", payload="dl_echo", input_collection="tape",
              granularity="coarse", start={})
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    st = ddm.stage_collection("tape", workers=2, max_attempts=2,
                              backoff=0.001)
    idds.pump_until(
        lambda: idds.request_status(rid)["status"] == "finished",
        timeout=30, interval=0.005)
    assert idds.request_status(rid)["works"] == {"subfinished": 1}
    assert len(idds.ctx.processings) == 0  # nothing left to process
    st.shutdown()
    idds.close()


@pytest.mark.parametrize("kind", ["memory", "sqlite"])
def test_rank_guard_allows_failed_to_available(kind, tmp_path):
    """failed -> available is the one legal backward journal move (a
    hedge landing after the original stage exhausted its attempts);
    available -> failed stays blocked."""
    store = _store_factory(kind, tmp_path)()
    store.save_contents("c", [FileRef("f0", status="failed").to_dict()])
    store.save_contents("c", [
        FileRef("f0", available=True, status="available").to_dict()])
    (coll,) = store.load_collections()
    assert coll["files"][0]["status"] == "available"
    # the reverse never applies: a stale failed snapshot loses
    store.save_contents("c", [FileRef("f0", status="failed").to_dict()])
    (coll,) = store.load_collections()
    assert coll["files"][0]["status"] == "available"
    store.close()


def test_subscribe_idempotent_on_sub_id():
    idds = IDDS()
    a = idds.subscribe("c1", ["x"], sub_id="sub-fixed")
    b = idds.subscribe("c1", ["x"], sub_id="sub-fixed")
    assert a["sub_id"] == b["sub_id"] == "sub-fixed"
    assert idds.list_subscriptions()["total"] == 1
    idds.close()


# ------------------------------------------------------------ REST surface

@pytest.fixture
def gateway():
    gw = RestGateway(IDDS())
    gw.start()
    yield gw
    gw.stop()


def test_rest_collections_contents_filter_pagination(gateway):
    client = IDDSClient(gateway.url)
    gateway.idds.ctx.ddm.register_collection("data/raw", [
        FileRef(f"f{i}", size=i, available=i % 2 == 0) for i in range(6)])
    colls = client.list_collections()
    assert colls["total"] == 1
    (c,) = colls["collections"]
    assert c["name"] == "data/raw" and c["files"] == 6
    assert c["statuses"] == {"available": 3, "new": 3}
    # status filter + pagination
    page = client.list_contents("data/raw", status="available", limit=2,
                                offset=1)
    assert page["total"] == 3
    assert [f["name"] for f in page["contents"]] == ["f2", "f4"]
    assert page["limit"] == 2 and page["offset"] == 1
    # back-compat list helper
    assert len(client.lookup_contents("data/raw")) == 6
    # invalid filter -> 400 envelope
    with pytest.raises(IDDSClientError) as ei:
        client.list_contents("data/raw", status="nope")
    assert ei.value.status == 400
    with pytest.raises(IDDSClientError) as ei:
        client.list_contents("data/raw", limit=-1)
    assert ei.value.status == 400


def test_rest_subscription_lifecycle(gateway):
    client = IDDSClient(gateway.url)
    sub = client.subscribe("trainer", ["out.*"])
    assert sub["consumer"] == "trainer"
    assert client.list_subscriptions()["total"] == 1
    got = client.get_subscription(sub["sub_id"])
    assert got["collections"] == ["out.*"]
    # drive one output through the pipeline over the wire
    gateway.idds.ctx.ddm.register_collection(
        "tape", [FileRef("f0", size=1, available=True)])
    rid = client.submit_workflow(_carousel_workflow())
    client.wait(rid, timeout=30)

    deadline = time.monotonic() + 10
    while client.list_deliveries(sub["sub_id"])["total"] == 0:
        assert time.monotonic() < deadline
        time.sleep(0.02)
    (d,) = client.list_deliveries(sub["sub_id"],
                                  status="notified")["deliveries"]
    r = client.ack(sub["sub_id"], [d["delivery_id"]])
    assert r["acked"] == 1
    (d,) = client.list_deliveries(sub["sub_id"])["deliveries"]
    assert d["status"] == "acked"
    # healthz carries the content/delivery tallies
    hz = client.healthz()
    assert hz["deliveries"]["subscriptions"] == 1
    assert hz["deliveries"]["acked"] == 1
    assert hz["contents"]["delivered"] >= 1
    # 404s
    with pytest.raises(KeyError):
        client.get_subscription("sub-nope")
    with pytest.raises(KeyError):
        client.ack(sub["sub_id"], ["dlv-nope"])
    # bad ack body -> 400
    with pytest.raises(IDDSClientError) as ei:
        client.ack(sub["sub_id"], [])
    assert ei.value.status == 400


# --------------------------------------------- carousel -> workers (e2e)

def test_carousel_to_live_workers_over_rest(tmp_path):
    """The paper's flagship scenario as one flow: a file-backed
    collection staged through CarouselDDM dispatches per-file
    processings as shards land; pull-based workers complete them over
    REST; content rows are journaled and /v1 reflects terminal states."""
    cold = _mk_cold(4)
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    store = SqliteStore(str(tmp_path / "state.db"))
    idds = IDDS(ddm=ddm, store=store,
                executor=DistributedWFM(lease_ttl=5.0))
    gw = RestGateway(idds)
    gw.start()
    stop = threading.Event()
    agents = [WorkerAgent(gw.url, worker_id=f"cw-{i}",
                          poll_interval=0.02) for i in range(2)]
    threads = [threading.Thread(target=a.run, args=(stop,), daemon=True)
               for a in agents]
    st = None
    try:
        for t in threads:
            t.start()
        client = IDDSClient(gw.url)
        sub = client.subscribe("trainer", ["out.tape"])
        ddm.register_from_cold("tape")
        wf = _carousel_workflow()
        # worker payloads resolve locally; dl_echo is registered in this
        # process, which is where the agents run
        rid = client.submit_workflow(wf, requester="alice")
        st = ddm.stage_collection("tape", workers=2)
        info = client.wait(rid, timeout=60)
        assert info["works"] == {"finished": 1}
        page = client.list_contents("tape", status="delivered")
        assert page["total"] == 4
        # every processing carried exactly one input file
        procs = client.list_processings(rid)["processings"]
        assert len(procs) == 4
        assert all(len(p["input_files"]) == 1 for p in procs)
        assert sum(a.jobs_done for a in agents) == 4
        # deliveries for the subscribed output collection
        deadline = time.monotonic() + 10
        while client.list_deliveries(sub["sub_id"])["total"] < 4:
            assert time.monotonic() < deadline
            time.sleep(0.02)
        dels = client.list_deliveries(sub["sub_id"])["deliveries"]
        client.ack(sub["sub_id"], [d["delivery_id"] for d in dels])
        deadline = time.monotonic() + 10
        while client.list_contents("out.tape",
                                   status="delivered")["total"] < 4:
            assert time.monotonic() < deadline
            time.sleep(0.02)
        # journaled on disk, not just live
        names = {c["name"] for c in store.load_collections()}
        assert {"tape", "out.tape"} <= names
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
        if st is not None:
            st.shutdown()
        gw.stop()
        idds.close()


# --------------------------------------------------- kill-and-recover

@pytest.mark.parametrize("kind", ["memory", "sqlite"])
def test_kill_and_recover_preserves_content_and_delivery_state(
        kind, tmp_path):
    """Crash the head mid-campaign: recovery must rebuild per-file
    content state (no file processed twice), the subscription registry,
    and the un-acked deliveries (re-notified, then ackable)."""
    mk = _store_factory(kind, tmp_path)
    cold = _mk_cold(3)
    ddm = CarouselDDM(cold, DiskCache(1 << 20))
    idds = IDDS(ddm=ddm, store=mk())
    sub = idds.subscribe("trainer", ["out.tape"])
    ddm.register_from_cold("tape")
    rid = idds.submit_workflow(_carousel_workflow())
    idds.pump()
    # two of three files staged + processed pre-crash
    for n in ("f0", "f1"):
        ddm.cache.put(n, cold.get(n).payload, 10, pin=False)
        ddm.set_available("tape", n)
        idds.ctx.bus.publish(M.T_COLLECTION_UPDATED,
                             {"collection": "tape", "file": n})
    idds.pump()
    assert idds.request_status(rid)["status"] == "running"
    assert idds.list_deliveries(sub["sub_id"])["total"] == 2
    # simulated crash: instance dropped without stop()/close()
    del idds

    ddm2 = CarouselDDM(_mk_cold(3), DiskCache(1 << 20))
    idds2 = IDDS(ddm=ddm2, store=mk())
    counts = idds2.recover()
    assert counts["subscriptions"] == 1
    statuses = {f["name"]: f["status"]
                for f in idds2.lookup_contents("tape")}
    assert statuses == {"f0": "delivered", "f1": "delivered",
                        "f2": "new"}
    # un-acked deliveries survived and are re-notified by the retry pass
    dels = idds2.list_deliveries(sub["sub_id"])
    assert dels["total"] == 2
    assert all(d["status"] == "notified" for d in dels["deliveries"])
    idds2.pump()
    # finish the campaign: stage the late file
    ddm2.cache.put("f2", ddm2.cold.get("f2").payload, 10, pin=False)
    ddm2.set_available("tape", "f2")
    idds2.ctx.bus.publish(M.T_COLLECTION_UPDATED,
                          {"collection": "tape", "file": "f2"})
    idds2.pump()
    assert idds2.request_status(rid)["status"] == "finished"
    # each file processed exactly once across the crash
    procs = idds2.store.load_processings()
    assert sorted(f for p in procs for f in p["input_files"]) == [
        "f0", "f1", "f2"]
    # ack everything; contents go terminal on the recovered head
    dels = idds2.list_deliveries(sub["sub_id"])["deliveries"]
    idds2.ack_delivery(sub["sub_id"], [d["delivery_id"] for d in dels])
    idds2.pump()
    dels = idds2.list_deliveries(sub["sub_id"])["deliveries"]
    assert {d["status"] for d in dels} <= {"acked", "notified"}
    assert all(f["status"] == "delivered"
               for f in idds2.lookup_contents("tape"))
    idds2.close()


# ------------------------------------------------- monotonic deadlines

def test_bus_wait_immune_to_wall_clock_steps(monkeypatch):
    """MessageBus deadlines must come from the monotonic clock: freeze
    (or jump) time.time and the waits still expire on schedule."""
    bus = M.MessageBus()
    real = time.time
    monkeypatch.setattr(time, "time", lambda: real() + 1e6)
    t0 = time.monotonic()
    assert bus.wait("t", timeout=0.05) is None
    assert bus.wait_any(("t",), timeout=0.05) is False
    assert time.monotonic() - t0 < 5.0
