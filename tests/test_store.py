"""Durable state store + crash recovery (paper §2's database-backed
catalogs): entity round trips on both backends, catalog pagination,
corrupt-file handling, kill-and-restart recovery with no duplicated
processings, idempotent recover(), and the REST listing endpoint's
edge cases.
"""
import os
import signal
import subprocess
import sys

import pytest

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.client import IDDSClient, IDDSClientError
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.store import InMemoryStore, SqliteStore, StoreError
from repro.core.workflow import (Branch, Condition, FileRef, Workflow,
                                 WorkTemplate)

reg.register_payload("store_double",
                     lambda params, inputs: {"x": params["x"] * 2})


@pytest.fixture(params=["memory", "sqlite"])
def store(request, tmp_path):
    if request.param == "memory":
        s = InMemoryStore()
    else:
        s = SqliteStore(str(tmp_path / "state.db"))
    yield s
    s.close()


def _chain_workflow(x=3) -> Workflow:
    wf = Workflow(name="store-chain")
    wf.add_template(WorkTemplate(name="a", payload="store_double"))
    wf.add_template(WorkTemplate(name="b", payload="store_double"))
    wf.add_condition(Condition(trigger="a", true_next=[Branch("b")]))
    wf.add_initial("a", {"x": x})
    return wf


# ------------------------------------------------------------ store unit

def test_request_upsert_and_get(store):
    info = {"request_id": "req-1", "workflow_id": "wf-1",
            "requester": "alice", "status": "accepted",
            "submitted_at": 1.0}
    store.save_request(info)
    store.save_request({**info, "status": "finished"})
    got = store.get_request("req-1")
    assert got["status"] == "finished"
    assert got["requester"] == "alice"
    assert store.get_request("req-nope") is None


def test_list_requests_filter_order_pagination(store):
    for i in range(5):
        store.save_request({"request_id": f"req-{i}", "workflow_id": "w",
                            "requester": "r", "submitted_at": float(i),
                            "status": "finished" if i % 2 else "running"})
    assert [r["request_id"] for r in store.list_requests()] == [
        f"req-{i}" for i in range(5)]  # insertion order
    assert store.count_requests() == 5
    assert store.count_requests(status="finished") == 2
    page = store.list_requests(status="running", limit=2, offset=1)
    assert [r["request_id"] for r in page] == ["req-2", "req-4"]
    assert store.list_requests(status="running", limit=1, offset=2) == \
        store.list_requests(status="running", offset=2, limit=1)
    assert store.list_requests(limit=0) == []
    assert store.list_requests(offset=99) == []


def test_works_and_processings_roundtrip(store):
    store.save_works("wf-1", [{"work_id": "w-1", "status": "new", "n": 1},
                              {"work_id": "w-2", "status": "new", "n": 2}])
    store.save_work("wf-1", {"work_id": "w-1", "status": "finished",
                             "n": 1})
    works = store.load_works()
    assert [(wid, w["work_id"], w["status"]) for wid, w in works] == [
        ("wf-1", "w-1", "finished"), ("wf-1", "w-2", "new")]
    store.save_processing({"proc_id": "p-1", "work_id": "w-1",
                           "status": "running"})
    store.save_processing({"proc_id": "p-1", "work_id": "w-1",
                           "status": "finished"})
    procs = store.load_processings()
    assert len(procs) == 1 and procs[0]["status"] == "finished"


def test_collection_contents_roundtrip(store):
    coll = {"name": "data/x", "scope": "idds",
            "files": [{"name": "f0", "size": 10, "available": True,
                       "processed": False},
                      {"name": "f1", "size": 20, "available": False,
                       "processed": False}]}
    store.save_collection(coll)
    coll["files"][1]["available"] = True
    store.save_collection(coll)  # upsert: availability flips in place
    (loaded,) = store.load_collections()
    assert loaded["name"] == "data/x"
    assert [f["available"] for f in loaded["files"]] == [True, True]
    assert [f["size"] for f in loaded["files"]] == [10, 20]


def test_empty_file_is_a_fresh_store(tmp_path):
    path = tmp_path / "empty.db"
    path.touch()  # zero bytes: sqlite treats it as a brand-new database
    s = SqliteStore(str(path))
    assert s.list_requests() == []
    idds = IDDS(store=s)
    assert idds.recover() == {k: 0 for k in idds.recover()}
    idds.close()


def test_corrupt_file_raises_store_error(tmp_path):
    path = tmp_path / "corrupt.db"
    path.write_bytes(b"this is definitely not a sqlite database\x00\x01")
    with pytest.raises(StoreError, match="unusable store file"):
        SqliteStore(str(path))


# ----------------------------------------------------- crash + recovery

@pytest.mark.parametrize("crash_after_rounds", [0, 1, 2, 3, 4])
def test_kill_and_restart_completes_without_duplicates(
        tmp_path, crash_after_rounds):
    """Submit N workflows, crash the head service after K daemon rounds,
    recover on a fresh IDDS over the same SQLite file: every request
    must reach 'finished' with no duplicated works or processings."""
    path = str(tmp_path / "state.db")
    n = 4
    idds = IDDS(store=SqliteStore(path))
    rids = [idds.submit_workflow(_chain_workflow(x=i)) for i in range(n)]
    for _ in range(crash_after_rounds):
        sum(d.process_once() for d in idds.daemons)
    # simulated crash: the instance (bus, daemons, in-memory state) is
    # dropped without stop()/close() — only the SQLite file survives
    del idds

    idds2 = IDDS(store=SqliteStore(path))
    idds2.recover()
    idds2.pump()
    for rid in rids:
        info = idds2.request_status(rid)
        assert info["status"] == "finished"
        assert info["works"] == {"finished": 2}
    # exactly one Processing per Work, exactly two Works per workflow
    by_work = {}
    for p in idds2.store.load_processings():
        by_work.setdefault(p["work_id"], []).append(p)
    assert len(by_work) == 2 * n
    assert all(len(ps) == 1 for ps in by_work.values())
    assert len(idds2.store.load_works()) == 2 * n
    idds2.close()


def test_recover_twice_does_not_duplicate_works(tmp_path):
    path = str(tmp_path / "state.db")
    idds = IDDS(store=SqliteStore(path))
    rid = idds.submit_workflow(_chain_workflow())
    for _ in range(2):  # first work finished, condition not yet evaluated
        sum(d.process_once() for d in idds.daemons)
    del idds

    idds2 = IDDS(store=SqliteStore(path))
    first = idds2.recover()
    second = idds2.recover()
    assert first["works"] > 0
    # second pass finds nothing new to load (replays are deduplicated by
    # the Marshaller's started-workflow guard and the works check)
    assert all(second[k] == 0 for k in
               ("requests", "workflows", "works", "processings",
                "requeued_processings"))
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 2}
    assert len(idds2.store.load_works()) == 2
    idds2.close()


def test_recovery_resumes_incremental_delivery(tmp_path):
    """Fine-granularity work: two of three files delivered pre-crash.
    After recovery the journaled collection re-seeds the DDM, already-
    processed files are NOT re-dispatched, and the late file completes
    the work."""
    path = str(tmp_path / "state.db")
    idds = IDDS(store=SqliteStore(path))
    idds.ctx.ddm.register_collection(
        "raw.store", [FileRef("f0", size=1, available=True),
                      FileRef("f1", size=1, available=True),
                      FileRef("f2", size=1, available=False)])
    wf = Workflow(name="carousel")
    wf.add_template(WorkTemplate(name="t", payload="noop",
                                 input_collection="raw.store",
                                 granularity="fine"))
    wf.add_initial("t", {})
    rid = idds.submit_workflow(wf)
    idds.pump()  # f0/f1 processed; work still waits on f2
    assert idds.request_status(rid)["status"] == "running"
    del idds

    idds2 = IDDS(store=SqliteStore(path))
    idds2.recover()
    coll = idds2.ctx.ddm.get_collection("raw.store")
    assert [f.available for f in coll.files] == [True, True, False]
    assert [f.processed for f in coll.files] == [True, True, False]
    idds2.pump()
    assert idds2.request_status(rid)["status"] == "running"
    idds2.ctx.ddm.set_available("raw.store", "f2")
    idds2.ctx.bus.publish(M.T_COLLECTION_UPDATED,
                          {"collection": "raw.store"})
    idds2.pump()
    assert idds2.request_status(rid)["status"] == "finished"
    procs = idds2.store.load_processings()
    assert sorted(f for p in procs for f in p["input_files"]) == [
        "f0", "f1", "f2"]  # each file exactly once across the crash
    idds2.close()


def test_recovery_preserves_retry_budget(tmp_path):
    """A processing journaled as FAILED with attempts remaining (crash
    mid-retry) must be requeued by recover(), not treated as terminally
    failed — otherwise a work that would have succeeded on retry is
    downgraded to subfinished."""
    path = str(tmp_path / "state.db")
    fails = {"n": 0}

    def flaky(proc):
        fails["n"] += 1
        return "injected fault" if fails["n"] <= 2 else None

    idds = IDDS(store=SqliteStore(path), fault_hook=flaky)
    wf = Workflow(name="retry")
    wf.add_template(WorkTemplate(name="t", payload="noop", max_attempts=3))
    wf.add_initial("t", {})
    rid = idds.submit_workflow(wf)
    # one full daemon round: attempts 1 and 2 fail and are journaled;
    # the crash lands before the Carrier runs attempt 3
    sum(d.process_once() for d in idds.daemons)
    (proc,) = idds.store.load_processings()
    assert proc["status"] == "failed" and proc["attempt"] == 2
    del idds

    idds2 = IDDS(store=SqliteStore(path))  # no fault hook: retry succeeds
    counts = idds2.recover()
    assert counts["requeued_processings"] == 1
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 1}  # finished, NOT subfinished
    (proc,) = idds2.store.load_processings()
    assert proc["status"] == "finished" and proc["attempt"] == 3
    idds2.close()


def test_recovery_after_clean_finish_is_noop(tmp_path):
    path = str(tmp_path / "state.db")
    idds = IDDS(store=SqliteStore(path))
    rid = idds.submit_workflow(_chain_workflow())
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"
    idds.close()

    idds2 = IDDS(store=SqliteStore(path))
    counts = idds2.recover()
    assert counts["requeued_processings"] == 0
    assert counts["replayed_events"] == 0
    assert idds2.pump() == 1  # already quiescent
    assert idds2.request_status(rid)["status"] == "finished"
    assert idds2.request_status(rid)["works"] == {"finished": 2}
    idds2.close()


# --------------------------------------------- REST listing + pagination

@pytest.fixture
def gateway(tmp_path):
    gw = RestGateway(IDDS(store=SqliteStore(str(tmp_path / "gw.db"))))
    gw.start()
    yield gw
    gw.stop()
    gw.idds.close()


def test_rest_listing_pagination(gateway):
    client = IDDSClient(gateway.url)
    rids = [client.submit_workflow(_chain_workflow(x=i)) for i in range(5)]
    for rid in rids:
        client.wait(rid, timeout=30)
    out = client.list_requests()
    assert out["total"] == 5
    assert [r["request_id"] for r in out["requests"]] == rids
    page = client.list_requests(status="finished", limit=2, offset=1)
    assert page["total"] == 5
    assert [r["request_id"] for r in page["requests"]] == rids[1:3]
    assert client.list_requests(status="accepted")["total"] == 0


def test_rest_listing_edge_cases(gateway):
    client = IDDSClient(gateway.url)
    rid = client.submit_workflow(_chain_workflow())
    client.wait(rid, timeout=30)
    assert client.list_requests(limit=0)["requests"] == []
    assert client.list_requests(limit=0)["total"] == 1
    past = client.list_requests(offset=50)
    assert past["requests"] == [] and past["total"] == 1
    with pytest.raises(IDDSClientError) as ei:
        client.list_requests(status="bogus")
    assert ei.value.status == 400 and ei.value.type == "BadRequest"
    with pytest.raises(IDDSClientError) as ei:
        client.list_requests(limit=-1)
    assert ei.value.status == 400
    with pytest.raises(IDDSClientError) as ei:
        client._get("/requests?limit=abc")
    assert ei.value.status == 400


def test_rest_survives_restart_on_same_store(tmp_path):
    """Full-stack kill-and-restart: submit over HTTP, drop the gateway +
    IDDS without letting the workflows finish, bring up a new gateway on
    the same SQLite file, and finish over HTTP."""
    path = str(tmp_path / "rest.db")
    gw = RestGateway(IDDS(store=SqliteStore(path)), manage_idds=False)
    gw.start()  # daemons never started: requests stay in flight
    client = IDDSClient(gw.url)
    rids = [client.submit_workflow(_chain_workflow(x=i)) for i in range(3)]
    gw.stop()

    idds2 = IDDS(store=SqliteStore(path))
    idds2.recover()
    with RestGateway(idds2) as gw2:
        client2 = IDDSClient(gw2.url)
        for rid in rids:
            info = client2.wait(rid, timeout=30)
            assert info["works"] == {"finished": 2}
        assert client2.list_requests(status="finished")["total"] == 3
    idds2.close()


# ------------------------------------------------------- clean shutdown

@pytest.mark.parametrize("sig", [signal.SIGINT, signal.SIGTERM])
def test_rest_cli_clean_shutdown_on_signal(tmp_path, sig):
    """python -m repro.core.rest must stop daemons and close the store
    on SIGINT/SIGTERM instead of dying mid-write."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.rest", "--port", "0",
         "--store", str(tmp_path / "cli.db")],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, env=env,
        text=True)
    try:
        line = proc.stdout.readline()
        assert "serving on" in line, line
        proc.send_signal(sig)
        out = proc.communicate(timeout=15)[0]
        assert proc.returncode == 0, (proc.returncode, out)
        assert "store closed" in out, out
    finally:
        if proc.poll() is None:
            proc.kill()
