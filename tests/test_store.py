"""Durable state store + crash recovery (paper §2's database-backed
catalogs): entity round trips on both backends, catalog pagination,
corrupt-file handling, kill-and-restart recovery with no duplicated
processings, idempotent recover(), the REST listing endpoint's edge
cases, a property/stress layer for the rank-guarded content upsert
(threaded shuffles, bulk vs one-row convergence), and a randomized
crash-recovery fuzz over the write-coalescing journal buffer.
"""
import os
import random
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.client import IDDSClient, IDDSClientError
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.store import (BufferedStore, InMemoryStore, SqliteStore,
                              StoreError, _content_rank)
from repro.core.workflow import (Branch, Condition, FileRef, Workflow,
                                 WorkTemplate)

reg.register_payload("store_double",
                     lambda params, inputs: {"x": params["x"] * 2})


@pytest.fixture(params=["memory", "sqlite"])
def store(request, tmp_path):
    if request.param == "memory":
        s = InMemoryStore()
    else:
        s = SqliteStore(str(tmp_path / "state.db"))
    yield s
    s.close()


def _chain_workflow(x=3) -> Workflow:
    wf = Workflow(name="store-chain")
    wf.add_template(WorkTemplate(name="a", payload="store_double"))
    wf.add_template(WorkTemplate(name="b", payload="store_double"))
    wf.add_condition(Condition(trigger="a", true_next=[Branch("b")]))
    wf.add_initial("a", {"x": x})
    return wf


# ------------------------------------------------------------ store unit

def test_request_upsert_and_get(store):
    info = {"request_id": "req-1", "workflow_id": "wf-1",
            "requester": "alice", "status": "accepted",
            "submitted_at": 1.0}
    store.save_request(info)
    store.save_request({**info, "status": "finished"})
    got = store.get_request("req-1")
    assert got["status"] == "finished"
    assert got["requester"] == "alice"
    assert store.get_request("req-nope") is None


def test_list_requests_filter_order_pagination(store):
    for i in range(5):
        store.save_request({"request_id": f"req-{i}", "workflow_id": "w",
                            "requester": "r", "submitted_at": float(i),
                            "status": "finished" if i % 2 else "running"})
    assert [r["request_id"] for r in store.list_requests()] == [
        f"req-{i}" for i in range(5)]  # insertion order
    assert store.count_requests() == 5
    assert store.count_requests(status="finished") == 2
    page = store.list_requests(status="running", limit=2, offset=1)
    assert [r["request_id"] for r in page] == ["req-2", "req-4"]
    assert store.list_requests(status="running", limit=1, offset=2) == \
        store.list_requests(status="running", offset=2, limit=1)
    assert store.list_requests(limit=0) == []
    assert store.list_requests(offset=99) == []


def test_works_and_processings_roundtrip(store):
    store.save_works("wf-1", [{"work_id": "w-1", "status": "new", "n": 1},
                              {"work_id": "w-2", "status": "new", "n": 2}])
    store.save_work("wf-1", {"work_id": "w-1", "status": "finished",
                             "n": 1})
    works = store.load_works()
    assert [(wid, w["work_id"], w["status"]) for wid, w in works] == [
        ("wf-1", "w-1", "finished"), ("wf-1", "w-2", "new")]
    store.save_processing({"proc_id": "p-1", "work_id": "w-1",
                           "status": "running"})
    store.save_processing({"proc_id": "p-1", "work_id": "w-1",
                           "status": "finished"})
    procs = store.load_processings()
    assert len(procs) == 1 and procs[0]["status"] == "finished"


def test_collection_contents_roundtrip(store):
    coll = {"name": "data/x", "scope": "idds",
            "files": [{"name": "f0", "size": 10, "available": True,
                       "processed": False},
                      {"name": "f1", "size": 20, "available": False,
                       "processed": False}]}
    store.save_collection(coll)
    coll["files"][1]["available"] = True
    store.save_collection(coll)  # upsert: availability flips in place
    (loaded,) = store.load_collections()
    assert loaded["name"] == "data/x"
    assert [f["available"] for f in loaded["files"]] == [True, True]
    assert [f["size"] for f in loaded["files"]] == [10, 20]


def test_empty_file_is_a_fresh_store(tmp_path):
    path = tmp_path / "empty.db"
    path.touch()  # zero bytes: sqlite treats it as a brand-new database
    s = SqliteStore(str(path))
    assert s.list_requests() == []
    idds = IDDS(store=s)
    assert idds.recover() == {k: 0 for k in idds.recover()}
    idds.close()


def test_corrupt_file_raises_store_error(tmp_path):
    path = tmp_path / "corrupt.db"
    path.write_bytes(b"this is definitely not a sqlite database\x00\x01")
    with pytest.raises(StoreError, match="unusable store file"):
        SqliteStore(str(path))


# ----------------------------------------------------- crash + recovery

@pytest.mark.parametrize("crash_after_rounds", [0, 1, 2, 3, 4])
def test_kill_and_restart_completes_without_duplicates(
        tmp_path, crash_after_rounds):
    """Submit N workflows, crash the head service after K daemon rounds,
    recover on a fresh IDDS over the same SQLite file: every request
    must reach 'finished' with no duplicated works or processings."""
    path = str(tmp_path / "state.db")
    n = 4
    idds = IDDS(store=SqliteStore(path))
    rids = [idds.submit_workflow(_chain_workflow(x=i)) for i in range(n)]
    for _ in range(crash_after_rounds):
        sum(d.process_once() for d in idds.daemons)
    # simulated crash: the instance (bus, daemons, in-memory state) is
    # dropped without stop()/close() — only the SQLite file survives
    del idds

    idds2 = IDDS(store=SqliteStore(path))
    idds2.recover()
    idds2.pump()
    for rid in rids:
        info = idds2.request_status(rid)
        assert info["status"] == "finished"
        assert info["works"] == {"finished": 2}
    # exactly one Processing per Work, exactly two Works per workflow
    by_work = {}
    for p in idds2.store.load_processings():
        by_work.setdefault(p["work_id"], []).append(p)
    assert len(by_work) == 2 * n
    assert all(len(ps) == 1 for ps in by_work.values())
    assert len(idds2.store.load_works()) == 2 * n
    idds2.close()


def test_recover_twice_does_not_duplicate_works(tmp_path):
    path = str(tmp_path / "state.db")
    idds = IDDS(store=SqliteStore(path))
    rid = idds.submit_workflow(_chain_workflow())
    for _ in range(2):  # first work finished, condition not yet evaluated
        sum(d.process_once() for d in idds.daemons)
    del idds

    idds2 = IDDS(store=SqliteStore(path))
    first = idds2.recover()
    second = idds2.recover()
    assert first["works"] > 0
    # second pass finds nothing new to load (replays are deduplicated by
    # the Marshaller's started-workflow guard and the works check)
    assert all(second[k] == 0 for k in
               ("requests", "workflows", "works", "processings",
                "requeued_processings"))
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 2}
    assert len(idds2.store.load_works()) == 2
    idds2.close()


def test_recovery_resumes_incremental_delivery(tmp_path):
    """Fine-granularity work: two of three files delivered pre-crash.
    After recovery the journaled collection re-seeds the DDM, already-
    processed files are NOT re-dispatched, and the late file completes
    the work."""
    path = str(tmp_path / "state.db")
    idds = IDDS(store=SqliteStore(path))
    idds.ctx.ddm.register_collection(
        "raw.store", [FileRef("f0", size=1, available=True),
                      FileRef("f1", size=1, available=True),
                      FileRef("f2", size=1, available=False)])
    wf = Workflow(name="carousel")
    wf.add_template(WorkTemplate(name="t", payload="noop",
                                 input_collection="raw.store",
                                 granularity="fine"))
    wf.add_initial("t", {})
    rid = idds.submit_workflow(wf)
    idds.pump()  # f0/f1 processed; work still waits on f2
    assert idds.request_status(rid)["status"] == "running"
    del idds

    idds2 = IDDS(store=SqliteStore(path))
    idds2.recover()
    coll = idds2.ctx.ddm.get_collection("raw.store")
    assert [f.available for f in coll.files] == [True, True, False]
    assert [f.processed for f in coll.files] == [True, True, False]
    idds2.pump()
    assert idds2.request_status(rid)["status"] == "running"
    idds2.ctx.ddm.set_available("raw.store", "f2")
    idds2.ctx.bus.publish(M.T_COLLECTION_UPDATED,
                          {"collection": "raw.store"})
    idds2.pump()
    assert idds2.request_status(rid)["status"] == "finished"
    procs = idds2.store.load_processings()
    assert sorted(f for p in procs for f in p["input_files"]) == [
        "f0", "f1", "f2"]  # each file exactly once across the crash
    idds2.close()


def test_recovery_preserves_retry_budget(tmp_path):
    """A processing journaled as FAILED with attempts remaining (crash
    mid-retry) must be requeued by recover(), not treated as terminally
    failed — otherwise a work that would have succeeded on retry is
    downgraded to subfinished."""
    path = str(tmp_path / "state.db")
    fails = {"n": 0}

    def flaky(proc):
        fails["n"] += 1
        return "injected fault" if fails["n"] <= 2 else None

    idds = IDDS(store=SqliteStore(path), fault_hook=flaky)
    wf = Workflow(name="retry")
    wf.add_template(WorkTemplate(name="t", payload="noop", max_attempts=3))
    wf.add_initial("t", {})
    rid = idds.submit_workflow(wf)
    # one full daemon round: attempts 1 and 2 fail and are journaled;
    # the crash lands before the Carrier runs attempt 3
    sum(d.process_once() for d in idds.daemons)
    (proc,) = idds.store.load_processings()
    assert proc["status"] == "failed" and proc["attempt"] == 2
    del idds

    idds2 = IDDS(store=SqliteStore(path))  # no fault hook: retry succeeds
    counts = idds2.recover()
    assert counts["requeued_processings"] == 1
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 1}  # finished, NOT subfinished
    (proc,) = idds2.store.load_processings()
    assert proc["status"] == "finished" and proc["attempt"] == 3
    idds2.close()


def test_recovery_after_clean_finish_is_noop(tmp_path):
    path = str(tmp_path / "state.db")
    idds = IDDS(store=SqliteStore(path))
    rid = idds.submit_workflow(_chain_workflow())
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"
    idds.close()

    idds2 = IDDS(store=SqliteStore(path))
    counts = idds2.recover()
    assert counts["requeued_processings"] == 0
    assert counts["replayed_events"] == 0
    assert idds2.pump() == 1  # already quiescent
    assert idds2.request_status(rid)["status"] == "finished"
    assert idds2.request_status(rid)["works"] == {"finished": 2}
    idds2.close()


# --------------------------------------------- REST listing + pagination

@pytest.fixture
def gateway(tmp_path):
    gw = RestGateway(IDDS(store=SqliteStore(str(tmp_path / "gw.db"))))
    gw.start()
    yield gw
    gw.stop()
    gw.idds.close()


def test_rest_listing_pagination(gateway):
    client = IDDSClient(gateway.url)
    rids = [client.submit_workflow(_chain_workflow(x=i)) for i in range(5)]
    for rid in rids:
        client.wait(rid, timeout=30)
    out = client.list_requests()
    assert out["total"] == 5
    assert [r["request_id"] for r in out["requests"]] == rids
    page = client.list_requests(status="finished", limit=2, offset=1)
    assert page["total"] == 5
    assert [r["request_id"] for r in page["requests"]] == rids[1:3]
    assert client.list_requests(status="accepted")["total"] == 0


def test_rest_listing_edge_cases(gateway):
    client = IDDSClient(gateway.url)
    rid = client.submit_workflow(_chain_workflow())
    client.wait(rid, timeout=30)
    assert client.list_requests(limit=0)["requests"] == []
    assert client.list_requests(limit=0)["total"] == 1
    past = client.list_requests(offset=50)
    assert past["requests"] == [] and past["total"] == 1
    with pytest.raises(IDDSClientError) as ei:
        client.list_requests(status="bogus")
    assert ei.value.status == 400 and ei.value.type == "BadRequest"
    with pytest.raises(IDDSClientError) as ei:
        client.list_requests(limit=-1)
    assert ei.value.status == 400
    with pytest.raises(IDDSClientError) as ei:
        client._get("/requests?limit=abc")
    assert ei.value.status == 400


def test_rest_survives_restart_on_same_store(tmp_path):
    """Full-stack kill-and-restart: submit over HTTP, drop the gateway +
    IDDS without letting the workflows finish, bring up a new gateway on
    the same SQLite file, and finish over HTTP."""
    path = str(tmp_path / "rest.db")
    gw = RestGateway(IDDS(store=SqliteStore(path)), manage_idds=False)
    gw.start()  # daemons never started: requests stay in flight
    client = IDDSClient(gw.url)
    rids = [client.submit_workflow(_chain_workflow(x=i)) for i in range(3)]
    gw.stop()

    idds2 = IDDS(store=SqliteStore(path))
    idds2.recover()
    with RestGateway(idds2) as gw2:
        client2 = IDDSClient(gw2.url)
        for rid in rids:
            info = client2.wait(rid, timeout=30)
            assert info["works"] == {"finished": 2}
        assert client2.list_requests(status="finished")["total"] == 3
    idds2.close()


# --------------------------------------- rank-guard property + stress

_STATUSES = ["new", "staging", "failed", "available", "delivered"]


def _content(name, status, size=1):
    """A content row whose flags are a pure function of its status, so
    any two write paths that accept the same status sequence must
    converge on byte-identical rows."""
    return {"name": name, "size": size,
            "available": status in ("available", "delivered"),
            "processed": status == "delivered",
            "status": status}


def _final_contents(store, collection):
    (coll,) = [c for c in store.load_collections()
               if c["name"] == collection]
    return {f["name"]: (f["status"], f["available"], f["processed"],
                        f["size"])
            for f in coll["files"]}


def test_rank_guard_property_threaded_shuffle(store):
    """Property: however N threads interleave an out-of-order stream of
    per-file transitions, each file ends at its max-rank status — the
    rank guard makes content journaling order-insensitive, which is
    what licenses the write-coalescing buffer to batch it."""
    rng = random.Random(0xC0FFEE)
    n_files, writes_per_file, n_threads = 30, 6, 6
    seqs = {f"f{i}": [rng.choice(_STATUSES)
                      for _ in range(writes_per_file)]
            for i in range(n_files)}
    expected = {name: max(seq, key=_content_rank)
                for name, seq in seqs.items()}
    ops = [(name, st) for name, seq in seqs.items() for st in seq]
    rng.shuffle(ops)

    errors = []

    def writer(chunk):
        try:
            for name, st in chunk:
                store.save_contents("prop", [_content(name, st)])
        except Exception as e:  # pragma: no cover — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(ops[i::n_threads],))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    final = _final_contents(store, "prop")
    assert set(final) == set(seqs)
    for name, st in expected.items():
        assert final[name] == (st, st in ("available", "delivered"),
                               st == "delivered", 1), name


@pytest.mark.parametrize("kind", ["memory", "sqlite"])
def test_bulk_and_one_row_content_paths_converge(kind, tmp_path):
    """The batched write path (save_contents with many rows /
    save_contents_bulk) must land the exact same final catalog as the
    one-row-per-call path for the same transition stream."""
    rng = random.Random(20260807)
    seqs = {f"f{i}": [rng.choice(_STATUSES) for _ in range(5)]
            for i in range(40)}
    ops = [(name, st) for name, seq in seqs.items() for st in seq]
    rng.shuffle(ops)

    def make(tag):
        return (InMemoryStore() if kind == "memory"
                else SqliteStore(str(tmp_path / f"{tag}.db")))

    one, bulk = make("one"), make("bulk")
    for name, st in ops:
        one.save_contents("c", [_content(name, st)])
    for i in range(0, len(ops), 16):  # same stream, 16-row batches
        bulk.save_contents_bulk(
            [("c", [_content(n, s) for n, s in ops[i:i + 16]])])
    assert _final_contents(one, "c") == _final_contents(bulk, "c")
    for name, seq in seqs.items():
        assert _final_contents(one, "c")[name][0] == \
            max(seq, key=_content_rank)
    one.close()
    bulk.close()


def test_buffered_store_coalesces_and_flushes_on_read(tmp_path):
    inner = SqliteStore(str(tmp_path / "buf.db"))
    buf = BufferedStore(inner, flush_interval_ms=10_000, max_batch=8)
    for i in range(7):  # below max_batch: nothing reaches the inner yet
        buf.save_contents("c", [_content(f"f{i}", "available")])
    assert buf.pending() == 7
    assert inner.load_collections() == []
    # reads see the writer's own buffered state (read-your-writes)
    assert len(_final_contents(buf, "c")) == 7
    assert buf.pending() == 0
    for i in range(8):  # 8 buffered ops == max_batch: flushed inline
        buf.save_contents("c", [_content(f"g{i}", "new")])
    assert buf.pending() == 0
    assert buf.flushes == 2 and buf.coalesced_ops == 15
    buf.close()


def test_buffered_store_validates_knobs(tmp_path):
    inner = InMemoryStore()
    with pytest.raises(ValueError):
        BufferedStore(inner, max_batch=0)
    with pytest.raises(ValueError):
        BufferedStore(inner, flush_interval_ms=0)


def test_buffered_store_claims_visible_to_peer_handle(tmp_path):
    """The ownership plane must NOT ride the write-coalescing buffer: a
    claim head A takes through a BufferedStore has to be durable and
    peer-visible IMMEDIATELY, or head B could claim the same workflow
    during the buffer's flush window and both would process it."""
    path = str(tmp_path / "claims.db")
    inner = SqliteStore(path)
    head_a = BufferedStore(inner, flush_interval_ms=10_000, max_batch=64)
    head_b = SqliteStore(path)  # a second process's handle
    try:
        # pile up unflushed content ops so a buffered claim would hide
        head_a.save_contents("c", [_content("f0", "new")])
        assert head_a.pending() > 0
        assert head_a.try_claim("workflow", "wf-1", "head-A", ttl_s=5.0)
        # inside the TTL the peer handle must see (and lose) the CAS
        assert head_b.try_claim("workflow", "wf-1", "head-B",
                                ttl_s=5.0) is False
        (c,) = head_b.list_claims("workflow")
        assert c["owner_id"] == "head-A"
        # release is synchronous too: the peer wins immediately after
        assert head_a.release_claim("workflow", "wf-1", "head-A")
        assert head_b.try_claim("workflow", "wf-1", "head-B", ttl_s=5.0)
        # expiry hands over without any cooperation from head A
        assert head_b.try_claim("workflow", "wf-2", "head-B",
                                ttl_s=0.05)
        time.sleep(0.08)
        assert head_a.try_claim("workflow", "wf-2", "head-A", ttl_s=5.0)
    finally:
        head_a.close()
        head_b.close()


# ------------------------------------------ crash-recovery fuzz (bulk)

def _fuzz_workflow(payload, n_jobs):
    wf = Workflow(name="fuzz")
    wf.add_template(WorkTemplate(name="t", payload=payload))
    for i in range(n_jobs):
        wf.add_initial("t", {"i": i})
    return wf


@pytest.mark.parametrize("kind", ["memory", "sqlite"])
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_crash_recovery_fuzz_bulk_journal(tmp_path, kind, seed):
    """Kill the head at a random point of a bulk-batched run (journal
    writes ride a BufferedStore, so the crash also drops whatever the
    coalescing buffer had not flushed) and recover: jobs completed
    before the crash must NOT re-execute (exactly-once), every job
    still finishes, and no journaled lease survives recovery."""
    rng = random.Random(7000 + seed)
    executions = {}
    exec_lock = threading.Lock()
    payload_name = f"fuzz_count_{kind}_{seed}"

    def counting(params, inputs):
        with exec_lock:
            executions[params["i"]] = executions.get(params["i"], 0) + 1
        return {"i": params["i"]}

    reg.register_payload(payload_name, counting)

    path = str(tmp_path / "fuzz.db")
    inner = SqliteStore(path) if kind == "sqlite" else InMemoryStore()
    buf = BufferedStore(inner, flush_interval_ms=10_000,
                        max_batch=rng.choice([2, 3, 5]))
    idds = IDDS(store=buf, executor=DistributedWFM(lease_ttl=30.0))
    n_jobs = rng.randint(4, 8)
    rid = idds.submit_workflow(_fuzz_workflow(payload_name, n_jobs))
    idds.pump()

    # multi-head guard: the head's workflow claim went through the
    # BufferedStore, but a peer's handle on the same state must still
    # lose the CAS inside claimed_until — a claim parked in the
    # coalescing buffer would let two heads process the same workflow
    peer = SqliteStore(path) if kind == "sqlite" else inner
    wf_claims = peer.list_claims("workflow")
    assert wf_claims, "pumping head should hold its workflow claim"
    for c in wf_claims:
        assert peer.try_claim("workflow", c["entity_id"], "peer-head",
                              5.0) is False, c
    if kind == "sqlite":
        peer.close()

    sched = idds.scheduler
    held = []
    for _ in range(rng.randint(1, 3 * n_jobs)):  # random journal point
        action = rng.random()
        if action < 0.5:
            job = sched.lease("fuzz-w")
            if job is not None:
                held.append(job)
        elif held and action < 0.85:
            job = held.pop(rng.randrange(len(held)))
            fn = reg.get_payload(job["payload"])
            sched.complete(job["job_id"], "fuzz-w",
                           result=fn(job["params"], job["input_files"]))
        else:
            sum(d.process_once() for d in idds.daemons)
    # simulated crash: buffer contents (unflushed lease/content ops) are
    # lost with the process; only the inner store's state survives
    del idds, buf

    store2 = SqliteStore(path) if kind == "sqlite" else inner
    # a completion is exactly-once from the moment the Carrier journals
    # its processing as finished; anything still in flight at the crash
    # is at-least-once by design (the lease is requeued)
    durable_pre_crash = {p["params"]["i"]
                         for p in store2.load_processings()
                         if p["status"] == "finished"}
    idds2 = IDDS(store=store2, executor=DistributedWFM(lease_ttl=30.0))
    idds2.recover()
    assert idds2.store.load_leases() == []  # no orphaned leases survive
    idds2.pump()
    for _ in range(4 * n_jobs):
        if idds2.request_status(rid)["status"] == "finished":
            break
        job = idds2.scheduler.lease("survivor")
        if job is not None:
            fn = reg.get_payload(job["payload"])
            idds2.scheduler.complete(
                job["job_id"], "survivor",
                result=fn(job["params"], job["input_files"]))
        idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished", (seed, info)
    assert info["works"] == {"finished": n_jobs}
    # exactly-once: a job whose completion was journaled pre-crash is
    # never re-executed, whatever the buffer lost
    for i in durable_pre_crash:
        assert executions[i] == 1, (i, executions)
    assert set(executions) == set(range(n_jobs))
    # one processing per work, all finished, across the crash
    procs = idds2.store.load_processings()
    assert len(procs) == n_jobs
    assert all(p["status"] == "finished" for p in procs)
    idds2.close()


# ------------------------------------------------------- clean shutdown

@pytest.mark.parametrize("sig", [signal.SIGINT, signal.SIGTERM])
def test_rest_cli_clean_shutdown_on_signal(tmp_path, sig):
    """python -m repro.core.rest must stop daemons and close the store
    on SIGINT/SIGTERM instead of dying mid-write."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.rest", "--port", "0",
         "--store", str(tmp_path / "cli.db")],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, env=env,
        text=True)
    try:
        line = proc.stdout.readline()
        assert "serving on" in line, line
        proc.send_signal(sig)
        out = proc.communicate(timeout=15)[0]
        assert proc.returncode == 0, (proc.returncode, out)
        assert "store closed" in out, out
    finally:
        if proc.poll() is None:
            proc.kill()
