"""Lease scheduler: priority dispatch, throttling, expiry/requeue
races, attempt accounting, idempotent leasing, and head-crash recovery
of orphaned leases (the distributed execution plane, head side)."""
import pytest

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.idds import IDDS
from repro.core.scheduler import (DistributedWFM, JobScheduler,
                                  SchedulerConflict)
from repro.core.store import InMemoryStore, SqliteStore
from repro.core.workflow import (Processing, ProcessingStatus, Workflow,
                                 WorkTemplate)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _sched(**kw):
    clock = FakeClock()
    kw.setdefault("default_ttl", 10.0)
    s = JobScheduler(clock=clock, **kw)
    s.attach(InMemoryStore())
    return s, clock


def _proc(pid, priority=0, queue="default", max_attempts=3):
    return Processing(proc_id=pid, work_id="w", payload="noop",
                      params={"priority": priority, "queue": queue},
                      max_attempts=max_attempts)


# -------------------------------------------------------------- dispatch

def test_priority_order():
    s, _ = _sched()
    for pid, pr in (("lo", 0), ("hi", 9), ("mid", 4)):
        s.enqueue(_proc(pid, priority=pr))
    order = [s.lease("w1")["job_id"] for _ in range(3)]
    assert order == ["hi", "mid", "lo"]
    assert s.lease("w1") is None


def test_fifo_within_priority():
    s, _ = _sched()
    for pid in ("a", "b", "c"):
        s.enqueue(_proc(pid))
    assert [s.lease("w")["job_id"] for _ in range(3)] == ["a", "b", "c"]


def test_queue_caps_throttle_leases():
    s, _ = _sched(queue_caps={"default": 1})
    s.enqueue(_proc("p1"))
    s.enqueue(_proc("p2"))
    job = s.lease("w1")
    assert job["job_id"] == "p1"
    assert s.lease("w2") is None  # queue at its outstanding-lease cap
    s.complete("p1", "w1", result={})
    assert s.lease("w2")["job_id"] == "p2"


def test_queue_routing():
    s, _ = _sched()
    s.enqueue(_proc("gpu-job", queue="gpu"))
    s.enqueue(_proc("cpu-job", queue="cpu"))
    assert s.lease("w1", queues=["gpu"])["job_id"] == "gpu-job"
    assert s.lease("w1", queues=["gpu"]) is None
    assert s.lease("w1", queues=["cpu", "gpu"])["job_id"] == "cpu-job"


def test_duplicate_enqueue_is_idempotent():
    s, _ = _sched()
    p = _proc("p1")
    s.enqueue(p)
    s.enqueue(p)  # duplicate bus delivery
    assert s.lease("w")["job_id"] == "p1"
    assert s.lease("w") is None


def test_lease_payload_shape():
    s, _ = _sched()
    p = Processing(proc_id="p1", work_id="w", payload="noop",
                   params={"x": 1}, input_files=["f0"], max_attempts=2)
    s.enqueue(p)
    job = s.lease("w1", ttl=5.0)
    assert job["payload"] == "noop"
    assert job["params"] == {"x": 1}
    assert job["input_files"] == ["f0"]
    assert job["attempt"] == 1 and job["max_attempts"] == 2
    assert job["lease"]["ttl"] == 5.0
    assert job["lease"]["worker_id"] == "w1"
    assert p.status == ProcessingStatus.RUNNING


# --------------------------------------------------- expiry and heartbeats

def test_heartbeat_renews_lease():
    s, clock = _sched(default_ttl=10.0)
    s.enqueue(_proc("p1"))
    s.lease("w1")
    clock.advance(8)
    s.heartbeat("p1", "w1")
    clock.advance(8)  # t=16: original deadline long gone, renewed holds
    assert s.expire() == 0
    s.complete("p1", "w1", result={})
    assert s.take_outcome("p1")[0] == "finished"


def test_expiry_requeues_exactly_once_with_attempt_accounting():
    s, clock = _sched(default_ttl=10.0)
    s.enqueue(_proc("p1"))
    job = s.lease("w1")
    assert job["attempt"] == 1
    clock.advance(11)
    assert s.expire() == 1
    assert s.expire() == 0  # requeued exactly once
    job2 = s.lease("w2")
    assert job2["job_id"] == "p1"
    assert job2["attempt"] == 2  # expiry consumed an attempt


def test_stale_worker_completion_is_conflict_with_no_state_change():
    s, clock = _sched(default_ttl=10.0)
    s.enqueue(_proc("p1"))
    s.lease("w1")
    clock.advance(11)  # w1's lease expires; job requeued
    job = s.lease("w2")
    assert job["job_id"] == "p1"
    with pytest.raises(SchedulerConflict):
        s.complete("p1", "w1", result={"stale": True})
    assert s.take_outcome("p1") is None  # no state change
    s.complete("p1", "w2", result={"fresh": True})
    assert s.take_outcome("p1") == ("finished", {"fresh": True}, None, 2)


def test_stale_heartbeat_is_conflict():
    s, clock = _sched(default_ttl=10.0)
    s.enqueue(_proc("p1"))
    s.lease("w1")
    clock.advance(11)
    with pytest.raises(SchedulerConflict):
        s.heartbeat("p1", "w1")


def test_double_completion_same_worker_is_idempotent():
    s, _ = _sched()
    s.enqueue(_proc("p1"))
    s.lease("w1")
    r1 = s.complete("p1", "w1", result={"x": 1})
    r2 = s.complete("p1", "w1", result={"x": 1})  # retried POST
    assert r1["duplicate"] is False and r2["duplicate"] is True
    # the outcome is delivered once and counters aren't double-bumped
    assert s.take_outcome("p1") == ("finished", {"x": 1}, None, 1)
    assert s.take_outcome("p1") is None
    (w,) = [w for w in s.workers() if w["worker_id"] == "w1"]
    assert w["jobs_completed"] == 1


def test_expiry_exhausts_attempts_into_failed_outcome():
    s, clock = _sched(default_ttl=10.0)
    s.enqueue(_proc("p1", max_attempts=2))
    s.lease("w1")
    clock.advance(11)
    s.expire()  # attempt 1 -> 2, requeued
    s.lease("w2")
    clock.advance(11)
    s.expire()  # attempts exhausted -> terminal failure
    status, result, error, attempt = s.take_outcome("p1")
    assert status == "failed" and attempt == 2
    assert "lease expired" in error
    assert s.lease("w3") is None


def test_worker_reported_error_becomes_failed_outcome():
    s, _ = _sched()
    s.enqueue(_proc("p1"))
    s.lease("w1")
    s.complete("p1", "w1", error="ValueError: boom")
    assert s.take_outcome("p1") == ("failed", None, "ValueError: boom", 1)


def test_idempotency_key_replays_same_job():
    s, _ = _sched()
    s.enqueue(_proc("p1"))
    s.enqueue(_proc("p2"))
    j1 = s.lease("w1", idempotency_key="k1")
    j1b = s.lease("w1", idempotency_key="k1")  # retried request
    assert j1["job_id"] == j1b["job_id"] == "p1"
    assert j1b["lease"]["lease_id"] == j1["lease"]["lease_id"]
    assert s.lease("w1", idempotency_key="k2")["job_id"] == "p2"


def test_lease_requires_worker_and_positive_ttl():
    s, _ = _sched()
    with pytest.raises(ValueError):
        s.lease("")
    with pytest.raises(ValueError):
        s.lease("w", ttl=0)


def test_active_leases_counts_concurrent_holds():
    """Completing one of two concurrent leases leaves the other counted
    (regression: complete() used to decrement active_leases twice)."""
    s, _ = _sched()
    s.enqueue(_proc("p1"))
    s.enqueue(_proc("p2"))
    s.lease("w1")
    s.lease("w1")
    (w,) = s.workers()
    assert w["active_leases"] == 2
    s.complete("p1", "w1", result={})
    (w,) = s.workers()
    assert w["active_leases"] == 1
    s.complete("p2", "w1", result={})
    (w,) = s.workers()
    assert w["active_leases"] == 0


def test_idempotency_keys_do_not_accumulate():
    """Keys die with their lease (regression: the key map used to grow
    by one entry per lease ever granted)."""
    s, _ = _sched()
    for i in range(5):
        s.enqueue(_proc(f"p{i}"))
    for i in range(5):
        s.lease("w1", idempotency_key=f"k{i}")
        s.complete(f"p{i}", "w1", result={})
    assert len(s._lease_keys) == 0


def test_workers_registry_and_connectivity():
    s, clock = _sched(worker_ttl=60.0)
    s.enqueue(_proc("p1"))
    s.lease("w1")
    s.lease("w2")  # nothing left, but the worker is now known
    assert s.worker_count() == 2
    clock.advance(120)
    assert s.worker_count() == 0
    stale = {w["worker_id"]: w["connected"] for w in s.workers()}
    assert stale == {"w1": False, "w2": False}


def test_worker_registry_prunes_stale_entries():
    """Long-silent workers with nothing leased drop out of the registry
    (worker ids embed pids, so churn would otherwise grow it forever)."""
    s, clock = _sched(worker_ttl=10.0)
    s.lease("ghost")  # registers, leases nothing (empty queue)
    clock.advance(150)  # > 10x worker_ttl
    s.lease("fresh")
    assert {w["worker_id"] for w in s.workers()} == {"fresh"}


def test_shutdown_stops_leasing():
    s, _ = _sched()
    s.enqueue(_proc("p1"))
    s.shutdown()
    assert s.lease("w1") is None


# --------------------------------------- DistributedWFM through the daemons

def _drain_as_worker(idds, worker_id="wk"):
    """Act as an in-process worker against the head's scheduler."""
    done = 0
    sched = idds.scheduler
    while True:
        job = sched.lease(worker_id)
        if job is None:
            return done
        fn = reg.get_payload(job["payload"])
        sched.complete(job["job_id"], worker_id,
                       result=fn(job["params"], job["input_files"]))
        done += 1


def test_distributed_wfm_executes_via_leases():
    idds = IDDS(executor=DistributedWFM())
    wf = Workflow(name="dist")
    wf.add_template(WorkTemplate(name="n", payload="noop"))
    wf.add_initial("n", {"x": 1})
    wf.add_initial("n", {"x": 2})
    rid = idds.submit_workflow(wf)
    idds.pump()  # quiesces with 2 jobs pending (nothing executes inline)
    assert idds.request_status(rid)["status"] == "running"
    assert _drain_as_worker(idds) == 2
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 2}
    assert idds.stats["jobs_leased"] == 2


def test_distributed_worker_failure_uses_carrier_retries():
    """A worker-reported error flows through the Carrier's retry path:
    re-submission, attempt + 1, success on the retry."""
    idds = IDDS(executor=DistributedWFM())
    wf = Workflow(name="retry")
    wf.add_template(WorkTemplate(name="n", payload="noop",
                                 max_attempts=3))
    wf.add_initial("n", {})
    rid = idds.submit_workflow(wf)
    idds.pump()
    sched = idds.scheduler
    job = sched.lease("bad-worker")
    sched.complete(job["job_id"], "bad-worker", error="RuntimeError: x")
    idds.pump()  # Carrier consumes the failure and resubmits
    job2 = sched.lease("good-worker")
    assert job2["job_id"] == job["job_id"]
    assert job2["attempt"] == 2
    sched.complete(job2["job_id"], "good-worker", result={"ok": True})
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"
    assert idds.stats["job_retries"] == 1


# ------------------------------------------------------------- recovery

def test_recover_requeues_orphaned_leases(tmp_path):
    """Head crash mid-lease: the journaled lease is orphaned, recover()
    requeues the job, the stale worker's completion gets a conflict, and
    the job is executed exactly once (by the new holder)."""
    path = str(tmp_path / "head.db")
    idds = IDDS(store=SqliteStore(path), executor=DistributedWFM())
    wf = Workflow(name="crash")
    wf.add_template(WorkTemplate(name="n", payload="noop"))
    wf.add_initial("n", {"x": 7})
    rid = idds.submit_workflow(wf)
    idds.pump()
    job = idds.scheduler.lease("doomed-worker")
    assert job is not None
    assert len(idds.store.load_leases()) == 1
    idds.ctx.store.close()  # crash: lease row survives in the store

    fresh = IDDS(store=SqliteStore(path), executor=DistributedWFM())
    counts = fresh.recover()
    assert counts["orphaned_leases"] == 1
    assert counts["requeued_processings"] == 1
    assert fresh.store.load_leases() == []  # second recover finds none
    fresh.pump()
    # the dead head's worker reports against the new head: rejected
    with pytest.raises(SchedulerConflict):
        fresh.scheduler.complete(job["job_id"], "doomed-worker",
                                 result={})
    executed = _drain_as_worker(fresh, "survivor")
    assert executed == 1  # exactly once, by the new lease holder
    fresh.pump()
    info = fresh.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 1}
    fresh.close()


def test_store_lease_roundtrip_both_backends(tmp_path):
    rows = [{"job_id": "p1", "lease_id": "l1", "worker_id": "w1",
             "queue": "default", "attempt": 1, "ttl": 30.0,
             "expires_at": 123.0}]
    for store in (InMemoryStore(),
                  SqliteStore(str(tmp_path / "leases.db"))):
        store.save_lease(rows[0])
        store.save_lease({**rows[0], "worker_id": "w2"})  # upsert
        loaded = store.load_leases()
        assert len(loaded) == 1 and loaded[0]["worker_id"] == "w2"
        store.delete_lease("p1")
        store.delete_lease("p1")  # idempotent
        assert store.load_leases() == []
        store.close()


# ----------------------------------------------------- blocking bus waits

def test_wait_any_wakes_on_publish():
    import threading
    import time as _time
    bus = M.MessageBus()

    def _publish_later():
        _time.sleep(0.05)
        bus.publish(M.T_NEW_WORKS, {"work_id": "w"})

    threading.Thread(target=_publish_later, daemon=True).start()
    t0 = _time.perf_counter()
    woke = bus.wait_any((M.T_NEW_WORKFLOWS, M.T_NEW_WORKS), timeout=5.0)
    elapsed = _time.perf_counter() - t0
    assert woke is True
    assert elapsed < 2.0  # condition wakeup, not a full timeout sleep
    assert bus.depth(M.T_NEW_WORKS) == 1  # wait_any consumes nothing


def test_wait_any_times_out_quickly_when_idle():
    bus = M.MessageBus()
    assert bus.wait_any((M.T_NEW_WORKS,), timeout=0.01) is False


# --------------------------------------- intelligence plane: fairness

def _iproc(pid, queue="default", priority=0, files=()):
    return Processing(proc_id=pid, work_id="w", payload="noop",
                      params={"priority": priority, "queue": queue},
                      input_files=list(files))


def test_affinity_never_starves_a_queue():
    """Aged jobs dispatch even under workers 100%-affine to another
    queue: the aging term outranks any affinity edge, so every starved
    job leases within one aging interval of becoming the oldest."""
    from repro.core.intel import IntelPlane

    s, clock = _sched()
    s.enable_intel(IntelPlane(aging_interval=30.0))
    starved = [f"cold-{i}" for i in range(3)]
    for pid in starved:
        s.enqueue(_iproc(pid, queue="cold", files=["cold/x"]))
    hot_seq = 0

    def refill_hot():
        nonlocal hot_seq
        s.enqueue(_iproc(f"hot-{hot_seq}", queue="hot",
                         files=["hot/h1"]))
        hot_seq += 1

    refill_hot()
    leased_cold = []
    # the worker's manifest is 100% affine to the hot queue, and the
    # hot queue never runs dry — yet every cold job must still lease
    for _ in range(40):
        if len(leased_cold) == len(starved):
            break
        job = s.lease("w1", manifest=["hot/h1"])
        assert job is not None
        if job["queue"] == "cold":
            leased_cold.append(job["job_id"])
        else:
            refill_hot()  # keep the favored queue perpetually full
        s.complete(job["job_id"], "w1", result={})
        clock.advance(10.0)
    assert leased_cold == starved  # all dispatched, in FIFO order
    assert s.intel.aging_promotions > 0


def test_affinity_prefers_manifest_holder_within_level():
    """Within one effective-priority level the scheduler routes a job
    to the worker already holding its inputs."""
    s, _ = _sched()
    s.enable_intel()
    s.enqueue(_iproc("a", files=["ds1/f1", "ds1/f2"]))
    s.enqueue(_iproc("b", files=["ds2/f1", "ds2/f2"]))
    # FIFO would hand out "a" first; the manifest says this worker
    # holds ds2, so "b" wins the scored dispatch
    job = s.lease("w1", manifest=["ds2/f1", "ds2/f2"])
    assert job["job_id"] == "b"
    assert s.lease("w1", manifest=["ds2/f1", "ds2/f2"])["job_id"] == "a"
    assert s.intel.affinity_hits == 1
    assert s.intel.affinity_misses == 1


def test_idempotent_replay_survives_affinity_change():
    """A retried lease with the same idempotency key returns the SAME
    job even when the manifest (and thus the affinity scoring) changed
    between the attempts — the replay is keyed on the grant."""
    s, _ = _sched()
    s.enable_intel()
    s.enqueue(_iproc("a", files=["ds1/f1"]))
    s.enqueue(_iproc("b", files=["ds2/f1"]))
    first = s.lease("w1", idempotency_key="K", manifest=["ds1/f1"])
    assert first["job_id"] == "a"
    # retry with a manifest now 100%-affine to the OTHER job
    replay = s.lease("w1", idempotency_key="K", manifest=["ds2/f1"])
    assert replay["job_id"] == "a"
    assert replay["lease"]["lease_id"] == first["lease"]["lease_id"]
    # and "b" is still pending for the next fresh lease
    assert s.lease("w1", idempotency_key="K2")["job_id"] == "b"


def test_intel_off_path_ignores_manifest():
    """Without enable_intel the manifest is accepted (wire compat) but
    dispatch stays strict FIFO-within-priority."""
    s, _ = _sched()
    s.enqueue(_iproc("a", files=["ds1/f1"]))
    s.enqueue(_iproc("b", files=["ds2/f1"]))
    assert s.intel is None
    job = s.lease("w1", manifest=["ds2/f1"])
    assert job["job_id"] == "a"  # FIFO, manifest changes nothing
