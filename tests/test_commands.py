"""Request lifecycle command plane: abort/suspend/resume/retry through
every layer — in-process pump mode, the lease scheduler (fencing live
workers), crash recovery on both store backends (exactly-once replay),
and the /v1 REST surface with its deprecated legacy aliases.
"""
import http.client
import json
import os
import time

import pytest

from repro.core import payloads as reg
from repro.core.client import ConflictError, IDDSClient
from repro.core.commands import CommandConflict
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM, SchedulerConflict
from repro.core.spec import WorkflowSpec
from repro.core.store import InMemoryStore, SqliteStore

reg.register_payload("cmd_double",
                     lambda params, inputs: {"x": params["x"] * 2})


def _chain_workflow(x=3):
    spec = WorkflowSpec("cmd-chain")
    a = spec.work("a", payload="cmd_double", start={"x": x})
    a.then(spec.work("b", payload="cmd_double"))
    return spec.build()


def _sleep_workflow(n_jobs=2, ms=30):
    spec = WorkflowSpec("cmd-sleep")
    spec.work("s", payload="sleep_ms", defaults={"ms": ms},
              start=[{} for _ in range(n_jobs)])
    return spec.build()


@pytest.fixture(params=["memory", "sqlite"])
def store_factory(request, tmp_path):
    """Factory returning a *fresh handle on the same persisted state*,
    so kill-and-restart works on both backends (the memory backend
    survives by sharing the instance, sqlite by sharing the file)."""
    if request.param == "memory":
        s = InMemoryStore()
        yield lambda: s
    else:
        path = str(tmp_path / "cmd.db")
        handles = []

        def make():
            h = SqliteStore(path)
            handles.append(h)
            return h

        yield make
        for h in handles:
            h.close()


# --------------------------------------------------------- pump-mode basics

def test_suspend_blocks_dispatch_then_resume_finishes():
    idds = IDDS()
    rid = idds.submit_workflow(_chain_workflow())
    cmd = idds.suspend(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "suspended"
    assert info["suspended"] is True
    assert info["works"] == {"activated": 1}  # created but never dispatched
    assert idds.get_command(rid, cmd["command_id"])["status"] == "done"
    # suspended is not stuck: the flag + command tally say why it idles
    assert info["commands"]["total"] == 1
    idds.resume(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "finished"
    assert info["suspended"] is False
    assert info["works"] == {"finished": 2}


def test_abort_cancels_works_and_is_terminal():
    idds = IDDS()
    rid = idds.submit_workflow(_chain_workflow())
    idds.abort(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "aborted"
    assert info["works"] == {"cancelled": 1}
    # steering an aborted request conflicts at submit time...
    with pytest.raises(CommandConflict):
        idds.resume(rid)
    with pytest.raises(CommandConflict):
        idds.retry(rid)
    # ...but a duplicate abort is an accepted no-op
    dup = idds.abort(rid)
    idds.pump()
    assert idds.get_command(rid, dup["command_id"])["status"] == "done"


def test_abort_midway_cancels_only_unfinished_works():
    """Abort after the first work finished: its result survives, the
    already-spawned successor is cancelled, and nothing new spawns."""
    idds = IDDS(executor=DistributedWFM(lease_ttl=30.0))
    rid = idds.submit_workflow(_chain_workflow(x=3))
    idds.pump_until(lambda: idds.scheduler.queue_depths())
    job_a = idds.scheduler.lease("w1")
    idds.scheduler.complete(job_a["job_id"], "w1", result={"x": 6})
    # pump until a finalized and its successor b is queued for dispatch
    idds.pump_until(lambda: idds.scheduler.queue_depths()
                    .get("default", {}).get("pending", 0) > 0)
    idds.abort(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "aborted"
    assert info["works"] == {"finished": 1, "cancelled": 1}
    wf = idds.get_workflow(rid)
    by_status = {w.status.value: w for w in wf.works.values()}
    assert by_status["finished"].result == {"x": 6}  # survived the abort
    assert idds.scheduler.lease("w2") is None  # b's job was revoked


def test_resume_requires_suspended_state():
    idds = IDDS()
    rid = idds.submit_workflow(_chain_workflow())
    with pytest.raises(CommandConflict):
        idds.resume(rid)
    with pytest.raises(ValueError):
        idds.command(rid, "explode")
    with pytest.raises(KeyError):
        idds.suspend("req-nonexistent")


def test_suspend_of_finished_request_conflicts():
    """Losing the race with completion must not mislabel a finished
    request as suspended (regression)."""
    idds = IDDS()
    rid = idds.submit_workflow(_chain_workflow())
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"
    with pytest.raises(CommandConflict):
        idds.suspend(rid)
    # the lenient apply path no-ops too (race: finished between submit
    # pre-check and the Commander's apply — inject the command directly,
    # exactly as a crash replay would deliver it)
    from repro.core import messaging as M
    from repro.core.commands import Command
    late = Command(request_id=rid, action="suspend",
                   workflow_id=idds._requests[rid]["workflow_id"],
                   command_id="cmd-late")
    with idds.ctx.lock:
        idds.ctx.register_command(late)
    idds.ctx.bus.publish(M.T_NEW_COMMANDS, {"command_id": "cmd-late"})
    idds.pump()
    d = idds.get_command(rid, "cmd-late")
    assert d["status"] == "done" and d["detail"]["noop"] is True
    assert idds.request_status(rid)["status"] == "finished"


def test_retry_while_suspended_stays_suspended():
    """Retrying a suspended request must not flip its catalog row to
    'running' while dispatch is still fenced (regression)."""
    from repro.core.workflow import FileRef

    def hopeless(params, inputs):
        raise RuntimeError("broken")

    reg.register_payload("cmd_hopeless2", hopeless)
    spec = WorkflowSpec("retry-susp")
    spec.work("f", payload="cmd_hopeless2", max_attempts=1, start={})
    # a second work waiting on an unavailable input keeps the request
    # non-terminal, so the suspend is legal
    spec.work("waiting", payload="noop", input_collection="retry-in",
              start={})
    idds = IDDS()
    idds.ctx.ddm.register_collection(
        "retry-in", [FileRef("f0", available=False)])
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    assert idds.request_status(rid)["works"] == {
        "subfinished": 1, "activated": 1}
    idds.suspend(rid)
    idds.pump()
    idds.retry(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "suspended" and info["suspended"]
    rows = idds.list_requests(status="suspended")["requests"]
    assert [r["request_id"] for r in rows] == [rid]
    # the fresh attempt parked: the payload did not run yet
    assert info["works"]["transforming"] == 1
    # resume releases the parked retry attempt (fails again -> terminal)
    idds.resume(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "running"  # "waiting" still needs its input
    assert info["works"] == {"subfinished": 1, "activated": 1}


def test_command_id_reuse_across_requests_conflicts():
    idds = IDDS()
    rid_a = idds.submit_workflow(_chain_workflow())
    rid_b = idds.submit_workflow(_chain_workflow())
    idds.command(rid_a, "suspend", command_id="cmd-shared")
    with pytest.raises(CommandConflict):
        idds.command(rid_b, "suspend", command_id="cmd-shared")
    with pytest.raises(CommandConflict):
        idds.command(rid_a, "abort", command_id="cmd-shared")


def test_command_submission_is_idempotent_on_command_id():
    idds = IDDS()
    rid = idds.submit_workflow(_chain_workflow())
    first = idds.command(rid, "suspend", command_id="cmd-fixed")
    replay = idds.command(rid, "suspend", command_id="cmd-fixed")
    assert first["command_id"] == replay["command_id"] == "cmd-fixed"
    idds.pump()
    assert idds.list_commands(rid)["total"] == 1  # not applied twice
    # post-apply replay returns the journaled terminal state
    done = idds.command(rid, "suspend", command_id="cmd-fixed")
    assert done["status"] == "done"


def test_suspended_flag_rides_catalog_listing():
    idds = IDDS()
    rid = idds.submit_workflow(_chain_workflow())
    idds.suspend(rid)
    idds.pump()
    idds.request_status(rid)  # write-through
    rows = idds.list_requests(status="suspended")
    assert [r["request_id"] for r in rows["requests"]] == [rid]


# ------------------------------------------------------------------- retry

def test_retry_reruns_failed_processings_with_fresh_budget():
    calls = {"n": 0}

    def flaky(params, inputs):
        calls["n"] += 1
        if calls["n"] < 4:
            raise RuntimeError("transient")
        return {"ok": True}

    reg.register_payload("cmd_flaky", flaky)
    spec = WorkflowSpec("retryable")
    spec.work("f", payload="cmd_flaky", max_attempts=2, start={})
    idds = IDDS()
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    assert idds.request_status(rid)["works"] == {"subfinished": 1}
    assert calls["n"] == 2  # original budget exhausted
    cmd = idds.retry(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["works"] == {"finished": 1}
    assert calls["n"] == 4  # two fresh attempts: 3rd fails, 4th succeeds
    d = idds.get_command(rid, cmd["command_id"])
    assert d["status"] == "done"
    assert d["detail"] == {"works_retried": 1, "processings_retried": 1}


def test_retry_exhausting_attempt_budgets_repeatedly():
    def hopeless(params, inputs):
        raise RuntimeError("always broken")

    reg.register_payload("cmd_hopeless", hopeless)
    spec = WorkflowSpec("hopeless")
    spec.work("f", payload="cmd_hopeless", max_attempts=2, start={})
    idds = IDDS()
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    assert idds.request_status(rid)["works"] == {"subfinished": 1}
    assert idds.stats["job_attempts"] == 2
    for round_no in (1, 2):
        idds.retry(rid)
        idds.pump()
        # each retry grants a fresh budget, burns it, and re-terminates
        assert idds.request_status(rid)["works"] == {"subfinished": 1}
        assert idds.stats["job_attempts"] == 2 + 2 * round_no
    # a request with nothing failed retries as a no-op
    idds2 = IDDS()
    rid2 = idds2.submit_workflow(_chain_workflow())
    idds2.pump()
    cmd = idds2.retry(rid2)
    idds2.pump()
    d = idds2.get_command(rid2, cmd["command_id"])
    assert d["status"] == "done" and d["detail"]["noop"] is True


def test_retry_does_not_respawn_successors():
    """A failed trigger work whose condition already fired must not
    double-instantiate its successors when retried to success."""
    calls = {"n": 0}

    def once_flaky(params, inputs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("first time fails")
        return {"x": 1}

    reg.register_payload("cmd_once_flaky", once_flaky)
    spec = WorkflowSpec("respawn")
    a = spec.work("a", payload="cmd_once_flaky", max_attempts=1,
                  start={})
    a.then(spec.work("b", payload="cmd_double",
                     defaults={"x": 1}))
    idds = IDDS()
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    # a subfinished, but its (always) condition fired -> b ran fine
    assert idds.request_status(rid)["works"] == {
        "subfinished": 1, "finished": 1}
    idds.retry(rid)
    idds.pump()
    info = idds.request_status(rid)
    assert info["works"] == {"finished": 2}  # still 2 works, not 3


# ----------------------------------------------- scheduler / worker fencing

def test_abort_while_leased_fences_worker_no_double_completion():
    idds = IDDS(executor=DistributedWFM(lease_ttl=30.0))
    rid = idds.submit_workflow(_sleep_workflow(n_jobs=1))
    idds.pump_until(lambda: idds.scheduler.queue_depths())
    job = idds.scheduler.lease("w1")
    assert job is not None
    idds.abort(rid)
    idds.pump()
    # the worker observes the fence on heartbeat...
    with pytest.raises(SchedulerConflict):
        idds.scheduler.heartbeat(job["job_id"], "w1")
    # ...and a late completion is rejected the same way (no double
    # completion of a cancelled job)
    with pytest.raises(SchedulerConflict):
        idds.scheduler.complete(job["job_id"], "w1", result={"ok": True})
    info = idds.request_status(rid)
    assert info["status"] == "aborted"
    assert info["works"] == {"cancelled": 1}
    # the revoked job never resurfaces to another worker
    assert idds.scheduler.lease("w2") is None


def test_suspend_fences_lease_and_resume_releases_without_attempt_cost():
    idds = IDDS(executor=DistributedWFM(lease_ttl=30.0))
    rid = idds.submit_workflow(_sleep_workflow(n_jobs=1))
    idds.pump_until(lambda: idds.scheduler.queue_depths())
    job = idds.scheduler.lease("victim")
    idds.suspend(rid)
    idds.pump()
    with pytest.raises(SchedulerConflict):
        idds.scheduler.heartbeat(job["job_id"], "victim")
    assert idds.scheduler.lease("w2") is None  # fenced: not leasable
    depths = idds.scheduler.queue_depths()
    assert depths["default"]["suspended"] == 1
    idds.resume(rid)
    idds.pump()
    job2 = idds.scheduler.lease("w2")
    assert job2 is not None and job2["job_id"] == job["job_id"]
    # suspension consumed no attempt
    assert job2["attempt"] == job["attempt"]


# ----------------------------------------------------------- crash recovery

def test_suspend_kill_recover_resume_both_backends(store_factory):
    idds = IDDS(store=store_factory())
    rid = idds.submit_workflow(_chain_workflow())
    idds.suspend(rid)
    idds.pump()
    assert idds.request_status(rid)["status"] == "suspended"
    # "kill": a fresh head over the same persisted state
    idds2 = IDDS(store=store_factory())
    counts = idds2.recover()
    assert counts["commands"] == 1 and counts["replayed_commands"] == 0
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "suspended"  # fence survived the restart
    assert info["works"] == {"activated": 1}
    idds2.resume(rid)
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 2}  # exactly once: no dupes


def test_pending_command_replays_exactly_once(store_factory):
    """A command journaled but never applied (head died first) is
    replayed by recover() and applied exactly once."""
    store = store_factory()
    idds = IDDS(store=store)
    rid = idds.submit_workflow(_chain_workflow())
    idds.suspend(rid)  # journaled pending; NO pump: Commander never ran
    idds2 = IDDS(store=store_factory())
    counts = idds2.recover()
    assert counts["replayed_commands"] == 1
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "suspended"
    assert idds2.list_commands(rid)["commands"][0]["status"] == "done"
    # a second recover() must not re-apply it
    counts2 = idds2.recover()
    assert counts2["replayed_commands"] == 0
    idds2.pump()
    idds2.resume(rid)
    idds2.pump()
    assert idds2.request_status(rid)["works"] == {"finished": 2}


def test_retry_after_restart_finalizes(store_factory):
    """A retry issued against a *recovered* head must finalize: the
    Transformer's retry handler re-seeds the dispatched-inputs set that
    recovery skipped for then-terminal works (regression: the work
    wedged at `transforming` forever)."""
    calls = {"n": 0}

    def flaky(params, inputs):
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("transient")
        return {"ok": True}

    reg.register_payload("cmd_restart_flaky", flaky)
    spec = WorkflowSpec("retry-restart")
    spec.work("f", payload="cmd_restart_flaky", max_attempts=1, start={})
    idds = IDDS(store=store_factory())
    rid = idds.submit_workflow(spec.build())
    idds.pump()
    assert idds.request_status(rid)["works"] == {"subfinished": 1}
    # kill -> recover -> retry on the fresh head
    idds2 = IDDS(store=store_factory())
    idds2.recover()
    idds2.pump()
    idds2.retry(rid)
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 1}


def test_abort_replay_after_partial_apply_still_cancels(store_factory):
    """Crash window: the Commander journaled the request row 'aborted'
    but died before journaling the cancelled works; the replayed
    pending abort must still cancel them (regression: the replay
    degraded to a noop because control was rebuilt from the request
    row)."""
    store = store_factory()
    idds = IDDS(store=store)
    rid = idds.submit_workflow(_chain_workflow())
    idds.suspend(rid)
    idds.pump()  # works exist (activated) and stay fenced
    cmd = idds.abort(rid)  # journaled pending; Commander never runs
    # simulate the partial apply: request row updated, works untouched
    info = dict(idds.ctx.requests[rid])
    info["status"] = "aborted"
    store.save_request(info)
    # kill -> recover (control rebuilt as aborted, abort replayed)
    idds2 = IDDS(store=store_factory())
    counts = idds2.recover()
    assert counts["replayed_commands"] == 1
    idds2.pump()
    info2 = idds2.request_status(rid)
    assert info2["status"] == "aborted"
    assert info2["works"] == {"cancelled": 1}  # NOT left activated
    d = idds2.get_command(rid, cmd["command_id"])
    assert d["status"] == "done"
    assert d["detail"]["works_cancelled"] == 1


def test_aborted_request_stays_aborted_after_recovery(store_factory):
    idds = IDDS(store=store_factory())
    rid = idds.submit_workflow(_chain_workflow())
    idds.abort(rid)
    idds.pump()
    idds2 = IDDS(store=store_factory())
    idds2.recover()
    idds2.pump()
    info = idds2.request_status(rid)
    assert info["status"] == "aborted"
    assert info["works"] == {"cancelled": 1}  # nothing was resurrected


# ------------------------------------------------------------ REST surface

@pytest.fixture
def gateway():
    gw = RestGateway(IDDS())
    gw.start()
    yield gw
    gw.stop()


@pytest.fixture
def dist_gateway():
    gw = RestGateway(IDDS(executor=DistributedWFM(lease_ttl=5.0)))
    gw.start()
    yield gw
    gw.stop()


def test_v1_command_round_trip_over_the_wire(gateway):
    client = IDDSClient(gateway.url)
    # slow enough that the suspend lands while the request is running
    # (suspending an already-finished request is a 409 by design)
    rid = client.submit_workflow(_sleep_workflow(n_jobs=4, ms=300))
    cmd = client.suspend(rid, wait=True)
    assert cmd["status"] == "done"
    info = client.status(rid)
    assert info["status"] == "suspended" and info["suspended"] is True
    cmd = client.resume(rid, wait=True)
    assert cmd["status"] == "done"
    info = client.wait(rid, timeout=30)
    assert info["works"] == {"finished": 4}
    journal = client.list_commands(rid)
    assert [c["action"] for c in journal["commands"]] == [
        "suspend", "resume"]
    assert client.get_command(
        rid, journal["commands"][0]["command_id"])["status"] == "done"


def test_v1_abort_over_the_wire_with_live_worker(dist_gateway):
    """Acceptance: abort-while-leased over HTTP — the worker agent is
    fenced on heartbeat, drops the job, and nothing double-completes."""
    from repro.worker import WorkerAgent
    client = IDDSClient(dist_gateway.url)
    rid = client.submit_workflow(_sleep_workflow(n_jobs=1, ms=30))
    agent = WorkerAgent(dist_gateway.url, worker_id="fenced-w",
                        poll_interval=0.02)
    deadline = time.time() + 10
    job = None
    while job is None:
        job = client.lease_job("fenced-w")
        assert time.time() < deadline
        time.sleep(0.02)
    client.abort(rid, wait=True)
    with pytest.raises(ConflictError):
        client.heartbeat_job(job["job_id"], "fenced-w")
    with pytest.raises(ConflictError):
        client.complete_job(job["job_id"], "fenced-w", result={"ok": 1})
    info = client.wait(rid, timeout=30)
    assert info["status"] == "aborted"
    assert agent.jobs_done == 0


def test_command_validation_envelopes(gateway):
    client = IDDSClient(gateway.url)
    rid = client.submit_workflow(_chain_workflow())
    conn = http.client.HTTPConnection(gateway.host, gateway.port,
                                      timeout=5)
    for body, expect in ((b"{not json", 400), (b"{}", 400),
                         (b'{"action": 5}', 400),
                         (b'{"action": "explode"}', 400),
                         (b'{"action": "resume"}', 409)):
        conn.request("POST", f"/v1/requests/{rid}/commands", body=body)
        resp = conn.getresponse()
        assert resp.status == expect, body
        env = json.loads(resp.read())["error"]
        assert env["type"] == ("Conflict" if expect == 409
                               else "BadRequest")
    conn.request("POST", "/v1/requests/req-nope/commands",
                 body=b'{"action": "abort"}')
    resp = conn.getresponse()
    assert resp.status == 404
    resp.read()
    conn.close()
    with pytest.raises(KeyError):
        client.get_command(rid, "cmd-nope")


def test_transforms_and_processings_read_resources(gateway):
    client = IDDSClient(gateway.url)
    rid = client.submit_workflow(_chain_workflow())
    client.wait(rid, timeout=30)
    transforms = client.list_transforms(rid)
    assert transforms["total"] == 2
    assert sorted(t["template"] for t in transforms["transforms"]) == [
        "a", "b"]
    assert all(t["status"] == "finished"
               for t in transforms["transforms"])
    procs = client.list_processings(rid)
    assert procs["total"] == 2
    assert all(p["status"] == "finished" for p in procs["processings"])
    with pytest.raises(KeyError):
        client.list_transforms("req-nope")


def test_healthz_reports_command_plane(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    h = client.healthz()
    assert h["pending_commands"] == 0
    assert h["queues"] == {}
    rid = client.submit_workflow(_sleep_workflow(n_jobs=1))
    client.suspend(rid, wait=True)
    deadline = time.time() + 10
    while True:
        h = client.healthz()
        depths = h["queues"].get("default", {})
        if depths.get("suspended") or depths.get("pending"):
            break
        assert time.time() < deadline
        time.sleep(0.02)
    client.abort(rid, wait=True)


# ------------------------------------- legacy aliases + protocol hardening

def test_legacy_paths_send_deprecation_header(gateway):
    conn = http.client.HTTPConnection(gateway.host, gateway.port,
                                      timeout=5)
    conn.request("GET", "/healthz")
    r = conn.getresponse()
    assert r.status == 200
    assert r.getheader("Deprecation") == "true"
    assert '</v1/healthz>; rel="successor-version"' in \
        r.getheader("Link", "")
    r.read()
    # the canonical /v1 path carries no deprecation marker
    conn.request("GET", "/v1/healthz")
    r = conn.getresponse()
    assert r.status == 200
    assert r.getheader("Deprecation") is None
    r.read()
    conn.close()


def test_legacy_submit_and_status_still_work_unversioned(gateway):
    """Old clients keep working verbatim on the deprecated aliases."""
    from repro.core.requests import Request
    conn = http.client.HTTPConnection(gateway.host, gateway.port,
                                      timeout=5)
    body = Request(workflow=_chain_workflow()).to_json().encode()
    conn.request("POST", "/requests", body=body)
    r = conn.getresponse()
    assert r.status == 201
    rid = json.loads(r.read())["request_id"]
    conn.request("GET", f"/requests/{rid}")
    r = conn.getresponse()
    assert r.status == 200
    assert r.getheader("Deprecation") == "true"
    assert json.loads(r.read())["request_id"] == rid
    # v1-only resources have no unversioned alias
    conn.request("GET", f"/requests/{rid}/commands")
    r = conn.getresponse()
    assert r.status == 404
    r.read()
    conn.close()


def test_405_carries_allow_header(gateway):
    conn = http.client.HTTPConnection(gateway.host, gateway.port,
                                      timeout=5)
    # /v1/requests accepts GET and POST: DELETE must list both
    conn.request("DELETE", "/v1/requests")
    r = conn.getresponse()
    assert r.status == 405
    assert r.getheader("Allow") == "GET, POST"
    assert json.loads(r.read())["error"]["type"] == "MethodNotAllowed"
    # GET-only route advertises exactly GET, on legacy and v1 mounts
    for path in ("/v1/stats", "/stats"):
        conn.request("POST", path, body=b"{}")
        r = conn.getresponse()
        assert r.status == 405, path
        assert r.getheader("Allow") == "GET"
        r.read()
    conn.close()


def test_cli_steering_verbs(gateway, tmp_path):
    """The operator CLI drives the full steering vocabulary."""
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    base = [sys.executable, "-m", "repro.core.cli",
            "--url", gateway.url]

    def cli(*args):
        r = subprocess.run(base + list(args), capture_output=True,
                           text=True, env=env, timeout=30)
        assert r.returncode == 0, r.stderr
        return json.loads(r.stdout)

    wf_file = tmp_path / "wf.json"
    # long-running: CLI subprocess startup must land the suspend while
    # the request is still running
    wf_file.write_text(json.dumps(
        _sleep_workflow(n_jobs=4, ms=1500).to_dict()))
    rid = cli("submit", str(wf_file))["request_id"]
    assert cli("suspend", rid)["status"] == "done"
    assert cli("status", rid)["suspended"] is True
    assert cli("resume", rid)["status"] == "done"
    deadline = time.time() + 60
    while cli("status", rid)["status"] != "finished":
        assert time.time() < deadline
        time.sleep(0.05)
    assert [c["action"] for c in cli("commands", rid)["commands"]] == [
        "suspend", "resume"]
    assert cli("transforms", rid)["total"] == 4


if __name__ == "__main__":
    raise SystemExit(os.system(
        f"python -m pytest -x -q {__file__}") >> 8)
