"""Execution plane over the wire: REST lease lifecycle, 409 envelopes,
the client retry policy, and the e2e acceptance path — a workflow
submitted over REST completed by two separate worker *processes*."""
import http.client
import json
import os
import signal
import subprocess
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import pytest

from repro.core.client import ConflictError, IDDSClient, IDDSClientError
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.workflow import Workflow, WorkTemplate
from repro.worker import BatchWorkerAgent, WorkerAgent, WorkerPool

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sleep_workflow(n_jobs, ms=40, priority=0):
    wf = Workflow(name="worker-e2e")
    wf.add_template(WorkTemplate(
        name="s", payload="sleep_ms",
        defaults={"ms": ms, "priority": priority}))
    for _ in range(n_jobs):
        wf.add_initial("s", {})
    return wf


@pytest.fixture
def dist_gateway():
    gw = RestGateway(IDDS(executor=DistributedWFM(lease_ttl=5.0)))
    gw.start()
    yield gw
    gw.stop()


def _lease_with_retry(client, worker_id, timeout=10.0, **kw):
    """Lease once the daemons have dispatched the submitted workflow."""
    deadline = time.time() + timeout
    while time.time() < deadline:
        job = client.lease_job(worker_id, **kw)
        if job is not None:
            return job
        time.sleep(0.02)
    raise TimeoutError("no job became leasable")


# ------------------------------------------------------------ REST surface

def test_lease_execute_complete_over_rest(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    rid = client.submit_workflow(_sleep_workflow(1, ms=1))
    job = _lease_with_retry(client, "rest-w1")
    assert job["payload"] == "sleep_ms"
    hb = client.heartbeat_job(job["job_id"], "rest-w1")
    assert hb["ok"] is True
    r = client.complete_job(job["job_id"], "rest-w1",
                            result={"ok": True, "slept_ms": 1})
    assert r["ok"] is True and r["duplicate"] is False
    info = client.wait(rid, timeout=30)
    assert info["works"] == {"finished": 1}
    workers = client.list_workers()
    assert workers["connected"] == 1
    (w,) = workers["workers"]
    assert w["worker_id"] == "rest-w1" and w["jobs_completed"] == 1


def test_worker_agent_drives_workflow(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    rid = client.submit_workflow(_sleep_workflow(3, ms=5))
    agent = WorkerAgent(dist_gateway.url, worker_id="agent-1",
                        poll_interval=0.02)
    deadline = time.time() + 30
    while client.status(rid)["status"] != "finished":
        agent.run_once() or time.sleep(0.02)
        assert time.time() < deadline
    assert agent.jobs_done == 3


def test_stale_completion_is_409_envelope(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    client.submit_workflow(_sleep_workflow(1, ms=1))
    job = _lease_with_retry(client, "victim", ttl=0.2)
    time.sleep(0.4)  # lease expires; head requeues the job
    job2 = _lease_with_retry(client, "thief")
    assert job2["job_id"] == job["job_id"]
    assert job2["attempt"] == job["attempt"] + 1
    # raw wire check: exactly a 409 with a Conflict envelope
    conn = http.client.HTTPConnection(dist_gateway.host,
                                      dist_gateway.port, timeout=5)
    conn.request("POST", f"/jobs/{job['job_id']}/complete",
                 body=json.dumps({"worker_id": "victim",
                                  "result": {}}).encode())
    resp = conn.getresponse()
    assert resp.status == 409
    assert json.loads(resp.read())["error"]["type"] == "Conflict"
    conn.close()
    # typed SDK path raises ConflictError without retrying
    with pytest.raises(ConflictError):
        client.complete_job(job["job_id"], "victim", result={})
    # ...and the fresh holder still completes cleanly: no state change
    r = client.complete_job(job2["job_id"], "thief", result={"ok": True})
    assert r["ok"] is True


def test_requeued_exactly_once_after_expiry(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    client.submit_workflow(_sleep_workflow(1, ms=1))
    _lease_with_retry(client, "dying", ttl=0.2)
    time.sleep(0.5)
    assert _lease_with_retry(client, "w2") is not None
    assert client.lease_job("w3") is None  # requeued once, not twice


def test_jobs_endpoints_require_distributed_mode():
    with RestGateway(IDDS()) as gw:  # inline executor
        client = IDDSClient(gw.url)
        with pytest.raises(IDDSClientError) as ei:
            client.lease_job("w1")
        assert ei.value.status == 400
        assert ei.value.type == "NotDistributed"
        workers = client.list_workers()
        assert workers == {"workers": [], "connected": 0,
                           "distributed": False}


def test_lease_validation_envelopes(dist_gateway):
    conn = http.client.HTTPConnection(dist_gateway.host,
                                      dist_gateway.port, timeout=5)
    for body in (b"{not json", b'{"queues": ["a"]}',
                 b'{"worker_id": "w", "queues": "a"}',
                 b'{"worker_id": "w", "lease_ttl": -1}'):
        conn.request("POST", "/jobs/lease", body=body)
        resp = conn.getresponse()
        assert resp.status == 400, body
        assert json.loads(resp.read())["error"]["type"] == "BadRequest"
    # heartbeat/complete validate worker_id the same way as lease: a
    # non-string worker_id is a 400 envelope, not a 500
    for path in ("/jobs/x/heartbeat", "/jobs/x/complete"):
        for body in (b"{}", b'{"worker_id": ["w1"]}',
                     b'{"worker_id": 5}'):
            conn.request("POST", path, body=body)
            resp = conn.getresponse()
            assert resp.status == 400, (path, body)
            env = json.loads(resp.read())["error"]
            assert env["type"] == "BadRequest", (path, body)
    conn.close()


def test_agent_stops_on_auth_failure():
    """A worker with a bad token must stop, not retry forever."""
    with RestGateway(IDDS(tokens={"right"},
                          executor=DistributedWFM())) as gw:
        agent = WorkerAgent(gw.url, token="wrong", worker_id="badtok",
                            poll_interval=0.01)
        stop = threading.Event()
        t = threading.Thread(target=agent.run, args=(stop,), daemon=True)
        t.start()
        t.join(timeout=5)
        assert not t.is_alive()  # exited by itself, stop never set
        stop.set()


def test_healthz_reports_execution_plane(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    h = client.healthz()
    assert h["store"] == "InMemoryStore"
    assert h["distributed"] is True
    assert h["workers_connected"] == 0
    assert h["daemons"] == {"clerk": True, "marshaller": True,
                            "commander": True, "transformer": True,
                            "carrier": True, "conductor": True,
                            "publisher": True, "watchdog": True}
    client.lease_job("probe")  # empty lease still registers the worker
    assert client.healthz()["workers_connected"] == 1


# ------------------------------------------------------ bulk REST verbs

def _lease_many_with_retry(client, worker_id, n, timeout=10.0, **kw):
    deadline = time.time() + timeout
    jobs = []
    while time.time() < deadline and len(jobs) < n:
        jobs += client.lease_jobs(worker_id, n - len(jobs), **kw)
        if len(jobs) < n:
            time.sleep(0.02)
    assert len(jobs) == n, f"leased {len(jobs)}/{n}"
    return jobs


def test_multi_lease_batch_lifecycle(dist_gateway):
    """One multi-lease grabs the whole batch; batch heartbeat and batch
    complete return all-ok envelopes; the workflow finishes."""
    client = IDDSClient(dist_gateway.url)
    rid = client.submit_workflow(_sleep_workflow(5, ms=1))
    jobs = _lease_many_with_retry(client, "bulk-w", 5)
    assert len({j["job_id"] for j in jobs}) == 5
    hb = client.heartbeat_jobs([j["job_id"] for j in jobs], "bulk-w")
    assert hb["ok"] == 5 and hb["failed"] == 0
    assert all(r["ok"] and r["status"] == 200 for r in hb["results"])
    out = client.complete_jobs(
        [{"job_id": j["job_id"], "result": {"ok": True}} for j in jobs],
        "bulk-w")
    assert out["ok"] == 5 and out["failed"] == 0
    assert all(r["duplicate"] is False for r in out["results"])
    info = client.wait(rid, timeout=30)
    assert info["works"] == {"finished": 5}


def test_batch_partial_conflict_envelopes(dist_gateway):
    """A stale lease inside a batch yields a per-item 409 envelope; the
    other items still succeed — one bad job never poisons the batch."""
    client = IDDSClient(dist_gateway.url)
    client.submit_workflow(_sleep_workflow(2, ms=1))
    stale = _lease_with_retry(client, "mixed-w", ttl=0.2)
    live = _lease_with_retry(client, "mixed-w", ttl=30.0)
    time.sleep(0.4)  # first lease expires; head requeues its job
    hb = client.heartbeat_jobs([stale["job_id"], live["job_id"]],
                               "mixed-w")
    assert hb["ok"] == 1 and hb["failed"] == 1
    by_id = {r["job_id"]: r for r in hb["results"]}
    assert by_id[live["job_id"]]["ok"] is True
    bad = by_id[stale["job_id"]]
    assert bad["ok"] is False and bad["status"] == 409
    assert bad["error"]["type"] == "Conflict"
    out = client.complete_jobs(
        [{"job_id": stale["job_id"], "result": {}},
         {"job_id": live["job_id"], "result": {}}], "mixed-w")
    assert out["ok"] == 1 and out["failed"] == 1
    # completing again is a per-item duplicate, not an error
    again = client.complete_jobs(
        [{"job_id": live["job_id"], "result": {}}], "mixed-w")
    assert again["ok"] == 1
    assert again["results"][0]["duplicate"] is True


def test_bulk_verb_validation_envelopes(dist_gateway):
    conn = http.client.HTTPConnection(dist_gateway.host,
                                      dist_gateway.port, timeout=5)

    def post(path, body):
        conn.request("POST", path, body=json.dumps(body).encode())
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read())

    # n= bounds: 0, over the cap, and non-integers are 400 envelopes
    for q in ("n=0", "n=-3", "n=65", "n=abc"):
        status, env = post(f"/v1/jobs/lease?{q}", {"worker_id": "w"})
        assert status == 400, q
        assert env["error"]["type"] == "BadRequest", q
    # empty batches are rejected up front (nothing to do is a caller bug)
    status, env = post("/v1/jobs/heartbeat",
                       {"worker_id": "w", "job_ids": []})
    assert status == 400 and env["error"]["type"] == "BadRequest"
    status, env = post("/v1/jobs/complete",
                       {"worker_id": "w", "items": []})
    assert status == 400 and env["error"]["type"] == "BadRequest"
    # oversized batches are bounded, not silently truncated
    status, env = post("/v1/jobs/heartbeat",
                       {"worker_id": "w",
                        "job_ids": [f"j{i}" for i in range(257)]})
    assert status == 400 and env["error"]["type"] == "BadRequest"
    # item shape is validated per element
    status, env = post("/v1/jobs/complete",
                       {"worker_id": "w", "items": [{"result": {}}]})
    assert status == 400 and env["error"]["type"] == "BadRequest"
    # the batch verbs are v1-only: no unversioned legacy alias
    status, _ = post("/jobs/heartbeat",
                     {"worker_id": "w", "job_ids": ["j1"]})
    assert status == 404
    conn.close()


def test_multi_lease_idempotency_replay(dist_gateway):
    """Retrying a multi-lease with the same idempotency key replays the
    original grant; after some of those jobs complete, the replay
    returns only the still-held subset."""
    client = IDDSClient(dist_gateway.url)
    client.submit_workflow(_sleep_workflow(3, ms=1))
    deadline = time.time() + 10
    while client.list_workers()["queues"].get(
            "default", {}).get("pending", 0) < 3:
        assert time.time() < deadline
        time.sleep(0.02)

    conn = http.client.HTTPConnection(dist_gateway.host,
                                      dist_gateway.port, timeout=5)
    body = json.dumps({"worker_id": "replay-w",
                       "idempotency_key": "fixed-key-1"}).encode()

    def lease_again():
        conn.request("POST", "/v1/jobs/lease?n=3", body=body)
        resp = conn.getresponse()
        assert resp.status == 200
        return json.loads(resp.read())["jobs"]

    first = lease_again()
    assert len(first) == 3
    replay = lease_again()  # e.g. the response to `first` was lost
    assert [j["job_id"] for j in replay] == [j["job_id"] for j in first]
    client.complete_job(first[0]["job_id"], "replay-w", result={})
    partial = lease_again()  # only the still-held leases replay
    assert [j["job_id"] for j in partial] == \
        [j["job_id"] for j in first[1:]]
    conn.close()


def test_batch_worker_agent_drives_workflow(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    rid = client.submit_workflow(_sleep_workflow(6, ms=5))
    agent = BatchWorkerAgent(dist_gateway.url, concurrency=3,
                             worker_id="batch-agent", lease_ttl=5.0,
                             poll_interval=0.02)
    stop = threading.Event()
    t = threading.Thread(target=agent.run, args=(stop,), daemon=True)
    t.start()
    try:
        info = client.wait(rid, timeout=30)
    finally:
        stop.set()
        t.join(timeout=10)
    assert info["works"] == {"finished": 6}
    assert agent.stats()["jobs_done"] == 6
    assert agent.stats()["jobs_failed"] == 0
    # one identity on the head, not one per slot
    ids = [w["worker_id"] for w in client.list_workers()["workers"]]
    assert ids == ["batch-agent"]


def test_batch_agent_drops_lost_lease_from_batch(dist_gateway):
    """When the head revokes one lease out of a running batch (expiry
    here), the batch heartbeat's per-item 409 marks just that job lost:
    the agent skips its completion and finishes the rest."""
    client = IDDSClient(dist_gateway.url)
    client.submit_workflow(_sleep_workflow(2, ms=1))
    agent = BatchWorkerAgent(dist_gateway.url, concurrency=2,
                             worker_id="loser", lease_ttl=0.3)
    jobs = _lease_many_with_retry(client, "loser", 2, ttl=0.2)
    time.sleep(0.4)  # both leases expire while "executing"
    for j in jobs:
        with agent._lock:
            agent._running[j["job_id"]] = threading.Event()
    stop = threading.Event()
    t = threading.Thread(target=agent._heartbeat_loop, args=(stop,),
                         daemon=True)
    t.start()
    deadline = time.time() + 5
    while time.time() < deadline and not all(
            ev.is_set() for ev in agent._running.values()):
        time.sleep(0.02)
    stop.set()
    t.join(timeout=5)
    assert all(ev.is_set() for ev in agent._running.values())


def test_priority_orders_lease_dispatch(dist_gateway):
    client = IDDSClient(dist_gateway.url)
    client.submit_workflow(_sleep_workflow(1, ms=1, priority=1))
    client.submit_workflow(_sleep_workflow(1, ms=1, priority=9))
    # wait until both jobs are queued (GET /workers exposes depths)...
    deadline = time.time() + 10
    while True:
        depths = client.list_workers().get("queues", {})
        if depths.get("default", {}).get("pending", 0) >= 2:
            break
        assert time.time() < deadline
        time.sleep(0.02)
    # ...then the high-priority one must lease first
    first = _lease_with_retry(client, "w1")
    assert first["priority"] == 9


# --------------------------------------------------------- client retries

class _FlakyHandler(BaseHTTPRequestHandler):
    """Counts hits; returns 500 for the first ``fail_first`` requests
    per path, then 200 with a JSON body."""
    hits = {}
    fail_first = 1

    def log_message(self, *a):  # noqa: A003
        pass

    def _serve(self):
        n = self.hits.get(self.path, 0) + 1
        self.hits[self.path] = n
        length = int(self.headers.get("Content-Length", 0) or 0)
        if length:
            self.rfile.read(length)
        if n <= self.fail_first:
            payload = json.dumps(
                {"error": {"type": "Boom", "message": "transient"}})
            code = 500
        else:
            payload = json.dumps({"ok": True, "hits": n})
            code = 200
        data = payload.encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    do_GET = _serve
    do_POST = _serve


@pytest.fixture
def flaky_server():
    _FlakyHandler.hits = {}
    httpd = ThreadingHTTPServer(("127.0.0.1", 0), _FlakyHandler)
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    yield f"http://127.0.0.1:{httpd.server_address[1]}"
    httpd.shutdown()
    httpd.server_close()


def test_idempotent_get_retries_5xx(flaky_server):
    client = IDDSClient(flaky_server, retries=3, backoff=0.01)
    assert client._get("/stats")["ok"] is True
    assert _FlakyHandler.hits["/stats"] == 2  # one 500, one retry


def test_non_idempotent_post_never_retries_5xx(flaky_server):
    client = IDDSClient(flaky_server, retries=3, backoff=0.01)
    with pytest.raises(IDDSClientError) as ei:
        client._post("/mutate", {"x": 1})  # idempotent=False default
    assert "not retried" in str(ei.value)
    # the real HTTP status and server error type survive the wrap
    assert ei.value.status == 500 and ei.value.type == "Boom"
    assert _FlakyHandler.hits["/mutate"] == 1  # exactly one attempt


def test_opt_in_idempotent_post_retries_5xx(flaky_server):
    client = IDDSClient(flaky_server, retries=3, backoff=0.01)
    assert client._post("/jobs/lease", {"worker_id": "w"},
                        idempotent=True)["ok"] is True
    assert _FlakyHandler.hits["/jobs/lease"] == 2


def test_non_idempotent_post_never_retries_connection_error():
    # nothing listens here: connection refused on the first try
    client = IDDSClient("http://127.0.0.1:9", retries=3, backoff=0.01)
    t0 = time.perf_counter()
    with pytest.raises(IDDSClientError) as ei:
        client._post("/mutate", {"x": 1})
    assert "not retried" in str(ei.value)
    assert time.perf_counter() - t0 < 2.0  # no backoff sleeps happened


# ------------------------------------------------------- e2e (acceptance)

def test_workflow_completed_by_two_worker_processes(dist_gateway):
    """Acceptance: a workflow submitted over REST finishes with its
    processings executed by >= 2 separate worker processes pulling over
    the wire."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.worker",
         "--url", dist_gateway.url, "--concurrency", "2",
         "--poll-interval", "0.05", "--worker-id", f"e2e-proc{i}"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True) for i in range(2)]
    try:
        client = IDDSClient(dist_gateway.url)
        rid = client.submit_workflow(_sleep_workflow(10, ms=60))
        info = client.wait(rid, timeout=90)
        assert info["status"] == "finished"
        assert info["works"] == {"finished": 10}
        by_process = {}
        for w in client.list_workers()["workers"]:
            prefix = w["worker_id"].rsplit("-w", 1)[0]
            by_process[prefix] = (by_process.get(prefix, 0)
                                  + w["jobs_completed"])
        assert sum(by_process.values()) == 10
        assert sum(1 for v in by_process.values() if v > 0) >= 2, \
            by_process
    finally:
        for p in procs:
            p.send_signal(signal.SIGTERM)
        for p in procs:
            out, _ = p.communicate(timeout=20)
            assert p.returncode == 0, out[-2000:]


def test_worker_killed_mid_job_lease_expires_and_requeues(dist_gateway):
    """Worker dies mid-job: its lease expires, the head requeues the job
    exactly once, and a surviving in-process pool finishes the work."""
    client = IDDSClient(dist_gateway.url)
    rid = client.submit_workflow(_sleep_workflow(1, ms=1))
    victim_job = _lease_with_retry(client, "victim", ttl=0.3)
    # "kill" the victim: it simply never heartbeats or completes
    time.sleep(0.6)
    with WorkerPool(dist_gateway.url, concurrency=1,
                    worker_id="survivor", poll_interval=0.02):
        info = client.wait(rid, timeout=30)
    assert info["works"] == {"finished": 1}
    workers = {w["worker_id"]: w for w in client.list_workers()["workers"]}
    assert workers["survivor-w0"]["jobs_completed"] == 1
    assert workers["victim"]["jobs_completed"] == 0
    # the job ran once on the survivor with the expiry's attempt bump
    wf = client.get_workflow(rid)
    (work,) = wf.works.values()
    assert work.result["ok"] is True
    assert victim_job["attempt"] == 1
