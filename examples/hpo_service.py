"""HPO service (paper §3.2): central search-space scanning, asynchronous
evaluation of hyperparameter points on 'remote' workers — here the points
are REAL (tiny) training runs of the yi-6b smoke model.

    PYTHONPATH=src python examples/hpo_service.py
"""
from repro.configs.base import RunConfig
from repro.core import payloads as reg
from repro.core.hpo import HPOService, loguniform, uniform
from repro.core.idds import IDDS
from repro.launch.train import run_training


def train_trial(params, inputs):
    run = RunConfig(learning_rate=float(params["lr"]),
                    weight_decay=float(params["wd"]),
                    warmup_steps=2, total_steps=12, ce_block_v=64)
    res = run_training("yi-6b", smoke=True, steps=12, seq_len=32,
                       global_batch=2, carousel=False, run=run)
    return {"objective": res["last_loss"]}


reg.register_payload("hpo_train_trial", train_trial)


def main():
    idds = IDDS(sync=False, max_workers=4)   # 4 'grid GPU sites'
    idds.start()
    try:
        svc = HPOService(
            idds,
            {"lr": loguniform(1e-5, 3e-2), "wd": uniform(0.0, 0.3)},
            eval_payload="hpo_train_trial",
            optimizer="evolution",
            points_per_round=4, max_points=12, seed=0)
        res = svc.run(timeout=600)
    finally:
        idds.stop()
    print(f"{len(res.trials)} trials over {res.rounds} rounds "
          f"({res.failed_trials} failed)")
    for p, o in sorted(res.trials, key=lambda t: t[1])[:3]:
        print(f"  loss={o:.4f}  lr={p['lr']:.2e} wd={p['wd']:.3f}")
    print(f"best: {res.best_objective:.4f} at {res.best_point}")


if __name__ == "__main__":
    main()
