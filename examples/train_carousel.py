"""End-to-end driver: train a ~130M-param model for a few hundred steps,
fed by the Data Carousel (ColdStore -> Stager -> on-demand packing ->
incremental delivery), with async checkpoints and resume.

    PYTHONPATH=src python examples/train_carousel.py             # smoke
    PYTHONPATH=src python examples/train_carousel.py --full  # 300 steps

The --full run is the deliverable-(b) e2e driver: mamba2-130m (130M
params) on a synthetic corpus; expect several minutes on CPU.
"""
import argparse
import tempfile

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = args.out or tempfile.mkdtemp(prefix="idds_train_")
    if args.full:
        steps = args.steps or 300
        res = run_training(
            "mamba2-130m", smoke=False, steps=steps, seq_len=256,
            global_batch=8, out_dir=out, carousel=True, ckpt_every=50)
    else:
        steps = args.steps or 60
        res = run_training(
            "mamba2-130m", smoke=True, steps=steps, seq_len=64,
            global_batch=8, out_dir=out, carousel=True, ckpt_every=20)

    print(f"arch=mamba2-130m steps={res['steps']}")
    print(f"loss: {res['first_loss']:.4f} -> {res['last_loss']:.4f}")
    print(f"time-to-first-batch: {res['time_to_first_batch_s']:.2f}s "
          f"(training started while later shards were still on 'tape')")
    print(f"wall: {res['wall_s']:.1f}s   checkpoints in {out}")
    assert res["last_loss"] < res["first_loss"], "loss did not improve"


if __name__ == "__main__":
    main()
