"""REST quickstart: the quickstart workflow, but over the wire.

Starts a RestGateway (server thread, daemons threaded), submits the DG
workflow through the typed IDDSClient, and streams status until the
workflow finishes — the paper's "general Restful service to receive
requests from WFMS" (§2) end to end.

    PYTHONPATH=src python examples/rest_quickstart.py
"""
import time

from repro.core import payloads as reg
from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.spec import WorkflowSpec
from repro.core.workflow import Workflow

# payloads live server-side: the gateway process registers them, clients
# only ever reference them by name inside serialized workflows
reg.register_payload("simulate", lambda params, inputs: {
    "events": params["n_events"], "quality": params["n_events"] / 1000})
reg.register_payload("reconstruct", lambda params, inputs: {
    "tracks": int(params["events"] * 0.7)})


@reg.register_predicate("good_quality")
def good_quality(work, result):
    return bool(result and result.get("quality", 0) > 0.5)


@reg.register_binder("pass_events")
def pass_events(params, result):
    return {**params, **(result or {})}


def build_workflow() -> Workflow:
    spec = WorkflowSpec("rest-quickstart")
    reco = spec.work("reco", payload="reconstruct")
    spec.work("sim", payload="simulate") \
        .when("good_quality", then=[(reco, "pass_events")]) \
        .start({"n_events": 800}) \
        .start({"n_events": 200})  # fails the quality cut
    return spec.build()


def main():
    token = "quickstart-token"
    with RestGateway(IDDS(tokens={token})) as gw:
        print(f"gateway up at {gw.url}")
        client = IDDSClient(gw.url, token=token)
        print("health:", client.healthz())

        rid = client.submit_workflow(build_workflow(), requester="alice")
        print(f"submitted request {rid}; streaming status:")

        last = None
        deadline = time.time() + 30
        while True:
            info = client.status(rid)
            snap = (info["status"], info.get("works"))
            if snap != last:
                print(f"  {info['status']:9s} works={info.get('works', {})}")
                last = snap
            if info["status"] == "finished":
                break
            if time.time() > deadline:
                raise TimeoutError("workflow did not finish in 30s")
            time.sleep(0.01)

        wf = client.get_workflow(rid)
        for w in wf.works.values():
            print(f"  {w.template:5s} params={w.params} -> {w.result}")
        print("server stats:", client.stats())
        # only the 800-event sim passes the quality cut -> 3 works total
        assert info["works"] == {"finished": 3}, info
        print("rest quickstart passed")


if __name__ == "__main__":
    main()
