"""Quickstart: define a DG workflow, submit it through the JSON client
boundary, let the five daemons run it (paper Figs. 1-3 in one file).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import payloads as reg
from repro.core.idds import IDDS
from repro.core.requests import Request
from repro.core.spec import WorkflowSpec

# 1. register payloads (what PanDA would execute on the grid)
reg.register_payload("simulate", lambda params, inputs: {
    "events": params["n_events"], "quality": params["n_events"] / 1000})
reg.register_payload("reconstruct", lambda params, inputs: {
    "tracks": int(params["events"] * 0.7)})


@reg.register_predicate("good_quality")
def good_quality(work, result):
    return bool(result and result.get("quality", 0) > 0.5)


@reg.register_binder("pass_events")
def pass_events(params, result):
    return {**params, **(result or {})}


def main():
    # 2. client side: declare the workflow (a DG of Work templates)
    #    with the fluent WorkflowSpec builder
    spec = WorkflowSpec("quickstart")
    reco = spec.work("reco", payload="reconstruct")
    spec.work("sim", payload="simulate") \
        .when("good_quality", then=[(reco, "pass_events")]) \
        .start({"n_events": 800}) \
        .start({"n_events": 200})  # fails the quality cut
    wf = spec.build()

    # 3. serialize -> submit -> the server deserializes (Fig. 2)
    idds = IDDS()
    request_id = idds.submit(Request(workflow=wf, requester="alice").to_json())

    # 4. run the daemon pipeline (Clerk/Marshaller/Transformer/Carrier/
    #    Conductor) until quiescent
    idds.pump()

    # 5. inspect
    info = idds.request_status(request_id)
    print("request:", info["status"], info["works"])
    server_wf = idds.get_workflow(request_id)
    for w in server_wf.works.values():
        print(f"  {w.template:5s} params={w.params} -> {w.result}")
    print("daemon stats:", idds.stats)
    # only the 800-event sim passes the quality condition -> 3 works total
    assert info["works"] == {"finished": 3}


if __name__ == "__main__":
    main()
