"""Distributed workers quickstart: the pilot model, end to end.

Starts a head service whose Carrier dispatches through the lease
scheduler (``DistributedWFM``) instead of executing payloads inline,
spawns TWO separate worker processes (``python -m repro.worker``) that
pull jobs over HTTP, submits a workflow over the REST gateway, and
shows the work landing on both processes.

    PYTHONPATH=src python examples/distributed_workers.py
"""
import os
import signal
import subprocess
import sys

from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.spec import WorkflowSpec
from repro.core.workflow import Workflow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_JOBS = 8
TOKEN = "worker-token"


def build_workflow() -> Workflow:
    # sleep_ms is a built-in payload, so the worker processes need no
    # --payloads module; real deployments register their own on both
    # head (for validation) and workers (for execution)
    spec = WorkflowSpec("distributed-quickstart")
    spec.work("crunch", payload="sleep_ms", defaults={"ms": 60},
              start=[{} for _ in range(N_JOBS)])
    return spec.build()


def spawn_worker(url: str, name: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.worker", "--url", url,
         "--token", TOKEN, "--concurrency", "2",
         "--poll-interval", "0.05", "--worker-id", name],
        env=env)


def main():
    head = IDDS(tokens={TOKEN}, executor=DistributedWFM(lease_ttl=10.0))
    with RestGateway(head) as gw:
        print(f"head up at {gw.url} (distributed mode)")
        workers = [spawn_worker(gw.url, f"site-{c}") for c in "ab"]
        try:
            client = IDDSClient(gw.url, token=TOKEN)
            print("health:", client.healthz())
            rid = client.submit_workflow(build_workflow(),
                                         requester="alice")
            print(f"submitted {rid} ({N_JOBS} jobs); waiting...")
            info = client.wait(rid, timeout=60)
            print(f"finished: works={info['works']}")

            by_process = {}
            for w in client.list_workers()["workers"]:
                prefix = w["worker_id"].rsplit("-w", 1)[0]
                by_process[prefix] = (by_process.get(prefix, 0)
                                      + w["jobs_completed"])
            for prefix, n in sorted(by_process.items()):
                print(f"  {prefix}: completed {n} jobs")
            assert info["works"] == {"finished": N_JOBS}, info
            assert sum(by_process.values()) == N_JOBS, by_process
            assert sum(1 for v in by_process.values() if v > 0) >= 2, \
                f"expected >=2 worker processes to contribute: {by_process}"
        finally:
            for p in workers:
                p.send_signal(signal.SIGTERM)
            for p in workers:
                p.wait(timeout=15)
    print("distributed quickstart passed")


if __name__ == "__main__":
    main()
