"""Active Learning DG workflow (paper §3.3.2, Fig. 7): processing Works
and decision-making Works alternate in a condition-guarded cycle.  The
decision Work reads the upstream processing output and *re-binds the next
processing's parameters* (a learning-rate search here).

    PYTHONPATH=src python examples/active_learning.py
"""
from repro.configs.base import RunConfig
from repro.core import payloads as reg
from repro.core.active_learning import build_active_learning_workflow
from repro.core.idds import IDDS
from repro.launch.train import run_training


def process(params, inputs):
    """One (tiny) training run at the currently-hinted learning rate."""
    lr = float(params.get("lr", 1e-4))
    run = RunConfig(learning_rate=lr, warmup_steps=1, total_steps=8,
                    ce_block_v=64)
    res = run_training("qwen1.5-4b", smoke=True, steps=8, seq_len=16,
                       global_batch=2, carousel=False, run=run)
    return {"loss": res["last_loss"], "lr": lr}


def decide(params, inputs):
    """Keep doubling the LR while the loss keeps improving."""
    hist = params.get("history", [])
    cur = params["processing_result"]
    hist = hist + [[cur["lr"], cur["loss"]]]
    improving = len(hist) < 2 or hist[-1][1] < hist[-2][1] - 1e-4
    return {
        "decision": bool(improving and len(hist) < 6),
        "hint": {"lr": cur["lr"] * 2.0, "history": hist},
        "history": hist,
    }


reg.register_payload("al_process_train", process)
reg.register_payload("al_decide_lr", decide)


def main():
    wf = build_active_learning_workflow(
        process_payload="al_process_train",
        decide_payload="al_decide_lr",
        init_params={"lr": 1e-4},
        max_iterations=8)
    idds = IDDS()
    rid = idds.submit_workflow(wf)
    idds.pump()
    server_wf = idds.get_workflow(rid)
    rounds = [w for w in server_wf.works.values() if w.template == "decide"]
    last = max(rounds, key=lambda w: w.iteration)
    print(f"{len(rounds)} process->decide cycles")
    for lr, loss in last.result["history"]:
        print(f"  lr={lr:.2e}  loss={loss:.4f}")
    print("workflow:", server_wf.counts())


if __name__ == "__main__":
    main()
