"""The paper's flagship scenario, end to end: a Data Carousel feeding
distributed workers.

A head service mounts a ``CarouselDDM`` (synthetic tape ColdStore +
bounded DiskCache) as its DDM backend and dispatches through the lease
scheduler (``DistributedWFM``).  Two separate worker processes pull
jobs over HTTP.  A fine-granularity workflow is submitted over the REST
gateway against the tape collection; as the Stager lands shards, the
Transformer dispatches one Processing per file — workers start on the
FIRST staged file, long before the whole collection is on disk — and a
registered consumer subscription receives (and acks) per-file output
deliveries from the Conductor.

    PYTHONPATH=src python examples/carousel_workers.py
"""
import os
import signal
import subprocess
import sys
import time

from repro.carousel.ddm import CarouselDDM
from repro.carousel.storage import DiskCache
from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.spec import WorkflowSpec
from repro.data.synthetic import build_cold_store

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_SHARDS = 6
TOKEN = "carousel-token"
COLLECTION = "tape"
OUT = "out.tape"


def build_workflow():
    spec = WorkflowSpec("carousel-to-workers")
    # sleep_ms is a built-in payload, so the worker processes need no
    # --payloads module; one Processing per staged file (fine mode)
    spec.work("proc", payload="sleep_ms", defaults={"ms": 20},
              input_collection=COLLECTION, output_collection=OUT,
              granularity="fine", start={})
    return spec.build()


def spawn_worker(url: str, name: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.worker", "--url", url,
         "--token", TOKEN, "--concurrency", "2",
         "--poll-interval", "0.05", "--worker-id", name],
        env=env)


def main():
    # one slow tape drive: shards land one by one over ~1.5s, so the
    # head start of fine-granularity dispatch is visible in the output
    cold = build_cold_store(n_shards=N_SHARDS, drives=1,
                            mount_latency=0.25)
    ddm = CarouselDDM(cold, DiskCache(1 << 30))
    head = IDDS(tokens={TOKEN}, ddm=ddm,
                executor=DistributedWFM(lease_ttl=10.0))
    with RestGateway(head) as gw:
        print(f"head up at {gw.url} (carousel + distributed mode)")
        workers = [spawn_worker(gw.url, f"site-{c}") for c in "ab"]
        stager = None
        try:
            client = IDDSClient(gw.url, token=TOKEN)
            sub = client.subscribe("trainer", [OUT])
            ddm.register_from_cold(COLLECTION)
            rid = client.submit_workflow(build_workflow(),
                                         requester="alice")
            print(f"submitted {rid}; staging {N_SHARDS} shards "
                  f"from tape...")
            t0 = time.monotonic()
            stager = ddm.stage_collection(COLLECTION, workers=2)
            first_done = None
            while True:
                info = client.status(rid)
                procs = client.list_processings(rid)["processings"]
                done = sum(1 for p in procs if p["status"] == "finished")
                if done and first_done is None:
                    first_done = time.monotonic() - t0
                    landed = sum(
                        1 for f in client.lookup_contents(COLLECTION)
                        if f["status"] in ("available", "delivered"))
                    print(f"  first file processed after "
                          f"{first_done:.2f}s with only "
                          f"{landed}/{N_SHARDS} shards staged")
                if info["status"] == "finished":
                    break
                if time.monotonic() - t0 > 60:
                    raise TimeoutError(f"not finished: {info}")
                time.sleep(0.05)
            info = client.status(rid)
            print(f"finished: works={info['works']}")

            procs = client.list_processings(rid)["processings"]
            assert len(procs) == N_SHARDS, procs
            assert all(len(p["input_files"]) == 1 for p in procs)
            page = client.list_contents(COLLECTION, status="delivered")
            assert page["total"] == N_SHARDS, page
            print(f"contents: {N_SHARDS}/{N_SHARDS} tape files "
                  f"delivered (journaled per-file)")

            deadline = time.monotonic() + 15
            while client.list_deliveries(sub["sub_id"])["total"] \
                    < N_SHARDS:
                assert time.monotonic() < deadline
                time.sleep(0.05)
            dels = client.list_deliveries(sub["sub_id"])["deliveries"]
            r = client.ack(sub["sub_id"],
                           [d["delivery_id"] for d in dels])
            print(f"consumer acked {r['acked']} output deliveries")
            hz = client.healthz()
            print(f"healthz tallies: contents={hz['contents']} "
                  f"deliveries={hz['deliveries']}")
            assert hz["deliveries"]["acked"] == N_SHARDS

            # the journaled lifecycle timeline covers the whole run:
            # staging (tape -> disk), execute (lease -> completion),
            # and delivery (notify -> ack) spans, all with real
            # durations
            tr = client.trace(rid)
            names = {s["span"] for s in tr["spans"]}
            assert {"staging", "execute", "delivery"} <= names, names
            assert all(s["duration_s"] >= 0.0 for s in tr["spans"]), \
                tr["spans"]
            assert sum(1 for s in tr["spans"]
                       if s["span"] == "staging") == N_SHARDS
            longest = max(tr["spans"], key=lambda s: s["duration_s"])
            print(f"trace {tr['trace_id']}: {len(tr['spans'])} spans "
                  f"over {tr['duration_s']:.2f}s (longest: "
                  f"{longest['span']} {longest['duration_s']:.3f}s)")
        finally:
            for p in workers:
                p.send_signal(signal.SIGTERM)
            for p in workers:
                p.wait(timeout=15)
            if stager is not None:
                stager.shutdown()
    print("carousel-to-workers quickstart passed")


if __name__ == "__main__":
    main()
