"""Rubin Observatory exercise (paper §3.3.1): a middleware-generated DAG
with per-job dependencies, incrementally released through messaging.

    PYTHONPATH=src python examples/rubin_dag.py [--jobs 100000]
"""
import argparse
import time

from repro.core.dag import DAGScheduler, layered_dag
from repro.core.idds import IDDS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=20_000)
    args = ap.parse_args()

    jobs = layered_dag(args.jobs, width=max(100, args.jobs // 100),
                       fan_in=3, seed=0)
    idds = IDDS()
    sched = DAGScheduler(idds, jobs)
    t0 = time.time()
    out = sched.run_sync()
    wall = time.time() - t0
    print(f"jobs={out['jobs']} released={out['released']} "
          f"wall={wall:.2f}s ({out['jobs']/wall:,.0f} jobs/s)")
    print("daemon stats:", {k: v for k, v in idds.stats.items()
                            if k.startswith(("works", "job", "proc"))})


if __name__ == "__main__":
    main()
