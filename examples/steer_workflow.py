"""Steering quickstart: the request lifecycle command plane, end to end.

Starts a distributed head (lease scheduler + REST gateway) and one live
worker process, then drives a request through the full steering
vocabulary over the wire:

  submit -> suspend (worker leases are fenced; nothing dispatches)
         -> resume  (parked jobs flow again; the workflow finishes)
  submit -> abort   (works cancelled, leases revoked, request terminal)

    PYTHONPATH=src python examples/steer_workflow.py
"""
import os
import signal
import subprocess
import sys

from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.spec import WorkflowSpec

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOKEN = "steer-token"
N_JOBS = 4


def build_workflow(name: str):
    spec = WorkflowSpec(name)
    # slow enough that steering commands land while jobs are running
    spec.work("crunch", payload="sleep_ms", defaults={"ms": 150},
              start=[{} for _ in range(N_JOBS)])
    return spec.build()


def spawn_worker(url: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.worker", "--url", url,
         "--token", TOKEN, "--concurrency", "2",
         "--poll-interval", "0.05", "--worker-id", "steer-site"],
        env=env)


def main():
    head = IDDS(tokens={TOKEN}, executor=DistributedWFM(lease_ttl=5.0))
    with RestGateway(head) as gw:
        print(f"head up at {gw.url} (distributed mode)")
        worker = spawn_worker(gw.url)
        try:
            client = IDDSClient(gw.url, token=TOKEN)

            # -- suspend / resume ------------------------------------
            rid = client.submit_workflow(build_workflow("steer-sr"),
                                         requester="operator")
            cmd = client.suspend(rid, wait=True)
            assert cmd["status"] == "done", cmd
            info = client.status(rid)
            print(f"suspended {rid}: status={info['status']} "
                  f"suspended={info['suspended']}")
            assert info["status"] == "suspended" and info["suspended"]
            h = client.healthz()
            print("healthz queues:", h["queues"],
                  "pending_commands:", h["pending_commands"])

            cmd = client.resume(rid, wait=True)
            assert cmd["status"] == "done", cmd
            info = client.wait(rid, timeout=60)
            print(f"resumed {rid}: status={info['status']} "
                  f"works={info['works']}")
            assert info["works"] == {"finished": N_JOBS}, info

            # -- abort -----------------------------------------------
            rid2 = client.submit_workflow(build_workflow("steer-abort"),
                                          requester="operator")
            cmd = client.abort(rid2, wait=True)
            assert cmd["status"] == "done", cmd
            info2 = client.wait(rid2, timeout=60)
            print(f"aborted {rid2}: status={info2['status']} "
                  f"works={info2.get('works')}")
            assert info2["status"] == "aborted", info2

            journal = client.list_commands(rid)["commands"]
            print(f"command journal for {rid}: "
                  f"{[(c['action'], c['status']) for c in journal]}")
            print("steering quickstart passed")
        finally:
            worker.send_signal(signal.SIGTERM)
            worker.wait(timeout=20)


if __name__ == "__main__":
    main()
